#!/usr/bin/env python
"""The static invariant gate: trace-discipline lint + jaxpr audit + Pallas
kernel audit, as one blocking CI step.

Default mode audits the tree at HEAD and exits non-zero on ANY finding:

    PYTHONPATH=src python scripts/check_invariants.py

Layers (select a subset with ``--only``):

* ``lint``   — AST rules REX001-005 over ``src/repro`` (see
  ``repro.analysis.lint.RULES``; suppress a deliberate exception inline
  with ``# rex: disable=REXNNN``).
* ``jaxpr``  — traces every registered jit entry point (engine admit/rank/
  advance, kernel wrappers, fleet shard_map bodies) and walks the
  ClosedJaxpr for host callbacks, f64/weak-type promotions and dynamic
  shapes.
* ``kernel`` — proves every Pallas grid/BlockSpec index map in bounds over
  a ragged shape sweep and probes the (NEG_INF, -1) masked/padded-slot
  sentinel convention in interpret mode.

``--fixtures`` mode lints the planted-violation corpus under
``tests/fixtures/analysis`` instead and exits NON-zero when — and only
when — every ``# rex-expect: REXNNN=n`` expectation is met exactly.  CI
runs ``! check_invariants.py --fixtures``: if a rule ever stops firing (or
fires somewhere unexpected) the command exits 0 and the inverted gate
fails.  ``tests/test_analysis.py`` holds the per-rule exactness tests.
"""
from __future__ import annotations

import argparse
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")

_EXPECT_RE = re.compile(r"#\s*rex-expect:\s*(REX\d+)\s*=\s*(\d+)")


def _read_expectations(root: str) -> dict[tuple[str, str], int]:
    """(relpath, rule) -> expected count, from # rex-expect: headers."""
    out: dict[tuple[str, str], int] = {}
    for dirpath, _dirs, files in os.walk(root):
        for f in sorted(files):
            if not f.endswith(".py"):
                continue
            path = os.path.join(dirpath, f)
            rel = os.path.relpath(path, root)
            with open(path, encoding="utf-8") as fh:
                for rule, n in _EXPECT_RE.findall(fh.read()):
                    out[(rel, rule)] = out.get((rel, rule), 0) + int(n)
    return out


def run_fixtures() -> int:
    from repro.analysis.lint import RULES, lint_paths
    if not os.path.isdir(FIXTURES):
        print(f"ERROR: fixture corpus missing at {FIXTURES}")
        return 0          # fails the inverted CI gate
    expected = _read_expectations(FIXTURES)
    got: dict[tuple[str, str], int] = {}
    for v in lint_paths([FIXTURES], rel_to=FIXTURES):
        print(v)
        got[(v.path, v.rule)] = got.get((v.path, v.rule), 0) + 1

    ok = True
    for key in sorted(set(expected) | set(got)):
        e, g = expected.get(key, 0), got.get(key, 0)
        if e != g:
            ok = False
            print(f"FIXTURE MISMATCH {key[0]}: {key[1]} expected {e}, "
                  f"got {g}")
    fired = {rule for (_p, rule) in got}
    for rule in sorted(set(RULES) - fired):
        ok = False
        print(f"FIXTURE MISMATCH: rule {rule} never fired on the corpus")
    if not ok:
        return 0          # fails the inverted CI gate
    print(f"fixtures OK: {len(got)} expectation group(s), every rule "
          "demonstrated — exiting non-zero as the gate demo")
    return 1


def run_head(layers: list[str]) -> int:
    findings = []
    if "lint" in layers:
        from repro.analysis.lint import lint_paths
        findings += lint_paths([os.path.join(REPO, "src", "repro")],
                               rel_to=REPO)
    if "jaxpr" in layers:
        from repro.analysis.jaxpr_audit import audit_jaxprs
        findings += audit_jaxprs()
    if "kernel" in layers:
        from repro.analysis.kernel_audit import audit_kernels
        findings += audit_kernels()
    for v in findings:
        print(v)
    n = len(findings)
    print(f"check_invariants: {n} finding(s) across layers "
          f"[{', '.join(layers)}]")
    return 1 if n else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fixtures", action="store_true",
                    help="lint the planted-violation corpus; exits non-zero "
                         "iff every expectation is met (CI inverts this)")
    ap.add_argument("--only", nargs="+", default=["lint", "jaxpr", "kernel"],
                    choices=["lint", "jaxpr", "kernel"],
                    help="subset of audit layers to run")
    args = ap.parse_args(argv)
    if args.fixtures:
        return run_fixtures()
    return run_head(args.only)


if __name__ == "__main__":
    sys.exit(main())
