"""Calibration sweep: find feature-oracle params hitting the paper's baseline
operating point (precision ~0.51, recall ~0.81 on Duke, Fig. 11)."""
import itertools, sys, time
import numpy as np
from repro.core import (duke_like_network, simulate_network, build_gallery,
                        build_model, track_queries, TrackerParams)
from repro.core.features import FeatureParams, make_features
from repro.core.tracker import make_queries

net = duke_like_network()
vis = simulate_network(net, 2700, 5100, seed=0)
gal, ovf = build_gallery(vis, 24)
model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams, time_limit=3000)
q_vids, gt_vids = make_queries(vis, 100, seed=1)
print("visits", len(vis), "overflow", ovf, flush=True)

grid_sigma = [0.35, 0.45]
grid_delta = [0.40, 0.55]
grid_thresh = [0.20, 0.28, 0.36]
grid_ncl = [150, 300]
exit_t = 240

rows = []
for sig, dl, th, ncl in itertools.product(grid_sigma, grid_delta, grid_thresh, grid_ncl):
    feats, _ = make_features(vis, 2700, FeatureParams(noise_sigma=sig, cluster_delta=dl, n_clusters=ncl))
    pb = TrackerParams(scheme="all", match_thresh=th, exit_t=exit_t)
    rb = track_queries(model, vis, gal, feats, q_vids, gt_vids, pb, geo_adj=net.geo_adjacent).summary()
    pr = TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02, match_thresh=th, exit_t=exit_t)
    rr = track_queries(model, vis, gal, feats, q_vids, gt_vids, pr, geo_adj=net.geo_adjacent).summary()
    sav = rb['cost']/max(rr['cost'],1)
    print(f"sig={sig} dl={dl} th={th} ncl={ncl} | base P={rb['precision']:.2f} R={rb['recall']:.2f} "
          f"| rex P={rr['precision']:.2f} R={rr['recall']:.2f} sav={sav:4.1f}x "
          f"delay={rr['delay']:5.1f} resc={rr['rescued']}", flush=True)
