#!/usr/bin/env python
"""Docs link-check: every relative markdown link and every backticked
repo path in the given docs must resolve to a real file.

  python scripts/check_docs_links.py README.md ROADMAP.md docs/ARCHITECTURE.md

Checked:
  * ``[text](path)`` links — http(s)/mailto and pure #anchors are skipped;
  * `` `path/to/file.py` `` / `` `path/file.md` `` code spans containing a
    "/" — resolved against the doc's directory, the repo root, ``src/`` and
    ``src/repro/`` (prose shorthand like `kernels/ref.py`), with trailing
    ``::test_name`` suffixes stripped.

Exits non-zero listing every dangling reference.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(r"`([\w.\-/]+/[\w.\-]+\.(?:py|md))(?:::[\w.\-]+)?`")


def candidates(ref: str, doc_dir: Path):
    yield doc_dir / ref
    yield ROOT / ref
    yield ROOT / "src" / ref
    yield ROOT / "src" / "repro" / ref


def check(doc: Path) -> list[str]:
    text = doc.read_text()
    doc_dir = doc.parent
    bad = []
    refs = []
    for m in MD_LINK.finditer(text):
        target = m.group(1).split("#", 1)[0]
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        refs.append(target)
    refs += [m.group(1) for m in CODE_PATH.finditer(text)]
    for ref in refs:
        if not any(c.exists() for c in candidates(ref, doc_dir)):
            bad.append(f"{doc.relative_to(ROOT)}: dangling reference {ref!r}")
    return bad


def main() -> int:
    docs = [Path(a) if Path(a).is_absolute() else ROOT / a
            for a in sys.argv[1:]] or [ROOT / "README.md"]
    failures = []
    for doc in docs:
        if not doc.exists():
            failures.append(f"doc not found: {doc}")
            continue
        failures += check(doc)
    for f in failures:
        print(f, file=sys.stderr)
    if not failures:
        print(f"docs link-check OK ({len(docs)} docs)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
