#!/usr/bin/env python
"""Validate a directory of BENCH_*.json perf-trajectory files (CI hook).

Every file must be ``{"scenario": <name>, "records": [<row>, ...]}`` and
every non-derived row must carry the golden schema keys
(``benchmarks.scenarios.REQUIRED_BENCH_KEYS`` — imported, not duplicated,
so the check can never drift from the writer).  Exit 1 on any violation,
so the CI bench smoke fails when a sweep ships malformed trajectory rows.

Usage: python scripts/bench_schema_check.py <dir-with-BENCH_*.json>
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.scenarios import REQUIRED_BENCH_KEYS  # noqa: E402


def check_file(path: str) -> list[str]:
    errs = []
    with open(path) as f:
        doc = json.load(f)
    name = os.path.basename(path)
    if not isinstance(doc.get("scenario"), str):
        errs.append(f"{name}: top-level 'scenario' must be a string")
    recs = doc.get("records")
    if not isinstance(recs, list) or not recs:
        errs.append(f"{name}: top-level 'records' must be a non-empty list")
        return errs
    for i, rec in enumerate(recs):
        if not isinstance(rec, dict):
            errs.append(f"{name}: records[{i}] is not an object")
            continue
        if rec.get("derived"):
            continue
        missing = [k for k in REQUIRED_BENCH_KEYS if k not in rec]
        if missing:
            errs.append(f"{name}: records[{i}] "
                        f"(config={rec.get('config', '?')}) missing "
                        f"required keys {missing}")
    return errs


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    bench_dir = sys.argv[1]
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if not paths:
        print(f"bench_schema_check: no BENCH_*.json under {bench_dir}",
              file=sys.stderr)
        return 1
    errs = []
    for p in paths:
        errs.extend(check_file(p))
    for e in errs:
        print(f"SCHEMA VIOLATION: {e}", file=sys.stderr)
    total = len(paths)
    if errs:
        print(f"bench_schema_check: {len(errs)} violations in {total} files",
              file=sys.stderr)
        return 1
    print(f"bench_schema_check: {total} files ok "
          f"(required keys: {', '.join(REQUIRED_BENCH_KEYS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
