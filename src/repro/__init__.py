"""repro — ReXCam: resource-efficient cross-camera video analytics, as a JAX framework.

Layers:
  repro.api       — stable control-plane facade (profile / track / serve, SearchPolicy)
  repro.core      — the paper's contribution (spatio-temporal correlation filtering;
                    core.policy is the single admission/phase control plane)
  repro.models    — analytics backbone model zoo (10 assigned architectures)
  repro.kernels   — Pallas TPU kernels for the inference-plane hot spots
  repro.parallel  — logical-axis sharding rules for the production mesh
  repro.optim / .checkpoint / .data / .runtime — substrate services
  repro.launch    — mesh construction, multi-pod dry-run, train/serve drivers
"""

__version__ = "1.0.0"
