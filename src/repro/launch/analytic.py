"""Closed-form roofline model per (arch x shape x mesh) cell.

XLA:CPU's ``cost_analysis()`` counts while-loop bodies once (scan-heavy
programs are undercounted — see EXPERIMENTS.md §Dry-run caveat), so the
compute/memory roofline terms are derived from this analytic model of the
exact program we lower (same chunking, remat, sharding), while the
*collective* term comes from the loop-aware HLO parse
(``repro.launch.hlo_analysis``) of the compiled module, cross-checked against
the analytic estimate here.

All byte/flop counts are PER CHIP per step.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.launch.shapes import SHAPES
from repro.models.config import ModelConfig, TP_DEGREE

BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class CellModel:
    flops_chip: float
    hbm_chip: float
    coll_chip: float
    detail: dict


def _mm_params_per_token(cfg: ModelConfig) -> float:
    """Matmul params touched per decoder token (excl. embed gather, incl.
    unembed; MoE counts routed experts x capacity padding)."""
    D, F = cfg.d_model, cfg.d_ff
    Hp, KV, hd = cfg.num_padded_heads, cfg.num_kv_heads, cfg.head_dim
    attn = D * Hp * hd + 2 * D * KV * hd + Hp * hd * D

    def mamba1():
        di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
        return (D * 2 * di + di * (dtr + 2 * n) + dtr * di + di * D
                + cfg.ssm_conv * di + 24 * di * n)     # scan arithmetic lumped

    def mamba2():
        di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
        ssd_intra = 2 * nh * cfg.ssm_chunk * (n + cfg.ssm_head_dim)
        return (D * (2 * di + 2 * n + nh) + di * D
                + cfg.ssm_conv * (di + 2 * n) + ssd_intra)

    if cfg.family == "ssm":
        per_layer = mamba1() if cfg.ssm_version == 1 else mamba2()
        body = cfg.num_layers * per_layer
    elif cfg.family == "hybrid":
        n_super = cfg.num_layers // cfg.attn_every
        body = cfg.num_layers * mamba2() + n_super * (attn + 3 * D * F)
    elif cfg.family == "moe":
        expert = cfg.experts_per_token * cfg.capacity_factor * 3 * D * F
        body = cfg.num_layers * (attn + D * cfg.num_experts + expert)
    elif cfg.family == "audio":
        body = cfg.num_layers * (2 * attn + 3 * D * F)   # self + cross attn
    else:
        body = cfg.num_layers * (attn + 3 * D * F)
    return body + D * cfg.vocab_size                      # unembed


def _attn_score_flops(cfg: ModelConfig, B: int, S: int, kind: str,
                      causal_skip: bool) -> float:
    """Softmax-attention score+PV flops (global)."""
    Hp, hd = cfg.num_padded_heads, cfg.head_dim
    if cfg.family == "ssm":
        return 0.0
    n_attn = (cfg.num_layers // cfg.attn_every if cfg.family == "hybrid"
              else cfg.num_layers)
    if kind == "decode":
        return n_attn * B * 4.0 * S * Hp * hd            # one token vs cache S
    # blockwise masked computes the full S^2; the balanced schedule ~halves it
    factor = 0.55 if causal_skip else 1.0
    flops = n_attn * B * 4.0 * S * S * Hp * hd * factor
    if cfg.family == "audio":
        Te = cfg.encoder_seq
        flops += cfg.encoder_layers * B * 4.0 * Te * Te * Hp * hd  # bidir enc
        flops += cfg.num_layers * B * 4.0 * S * Te * Hp * hd       # cross
    return flops


def _weight_bytes_chip(cfg: ModelConfig, tp: int, dp: int) -> float:
    """Weights streamed per forward pass per chip (after FSDP all-gather each
    chip holds its TP shard of every live layer)."""
    n_total = cfg.param_count()
    if cfg.family == "moe":
        D, F = cfg.d_model, cfg.d_ff
        n_exp = cfg.num_layers * cfg.num_experts * 3 * D * F
        n_dense = n_total - n_exp
        return n_dense / tp * BF16 + n_exp / (dp * tp) * BF16
    return n_total / tp * BF16


def analytic_cell(cfg: ModelConfig, shape_name: str, *, multi_pod: bool = False,
                  causal_skip: bool = False) -> CellModel:
    s = SHAPES[shape_name]
    n_chips = 512 if multi_pod else 256
    tp = TP_DEGREE
    dp = n_chips // tp
    B, S = s.global_batch, s.seq_len
    kind = s.kind

    tokens = B * S if kind in ("train", "prefill") else B
    t_loc = max(tokens // dp, 1)

    # ---- FLOPs ----
    mm = 2.0 * _mm_params_per_token(cfg) * tokens
    attn = _attn_score_flops(cfg, B, S, kind, causal_skip)
    fwd = mm + attn
    mult = 4.0 if kind == "train" else 1.0               # bwd 2x + remat 1x
    flops_chip = fwd * mult / n_chips

    # ---- HBM bytes ----
    D, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    w_pass = _weight_bytes_chip(cfg, tp, dp)
    n_passes = 3 if kind == "train" else 1               # fwd, remat, bwd
    bytes_w = w_pass * n_passes
    n_total = cfg.param_count()
    bytes_opt = (24.0 + 8.0) * n_total / n_chips if kind == "train" else 0.0
    c_act = 56 if kind == "train" else 16
    bytes_act = c_act * D * L * t_loc * BF16 / 8  # /8: chunked fusion residency
    v_shard = tp if cfg.shard_vocab else 1
    bytes_logits = (3 if kind == "train" else 1) * t_loc * V / v_shard * F32
    bytes_kv = 0.0
    if kind == "decode" and cfg.family != "ssm":
        n_attn = (cfg.num_layers // cfg.attn_every if cfg.family == "hybrid" else L)
        kv_shards = dp * (tp if cfg.shard_kv_heads else 1)
        bytes_kv = 2.0 * S * cfg.num_kv_heads * cfg.head_dim * BF16 * n_attn * B / kv_shards
    if kind == "decode" and cfg.family in ("ssm", "hybrid"):
        di, n = cfg.d_inner, cfg.ssm_state
        bytes_kv += 2.0 * B * di * n * F32 * L / max(dp * tp, 1)
    hbm_chip = bytes_w + bytes_opt + bytes_act + bytes_logits + bytes_kv

    # ---- collective estimate (cross-check; primary = HLO parse) ----
    n_passes_ag = 2 if kind == "train" else 1
    ag = n_passes_ag * BF16 * n_total / tp * (dp - 1) / dp
    rs = (F32 * n_total / tp * (dp - 1) / dp) if kind == "train" else 0.0
    n_ar = (L * 2 * (3 if kind == "train" else 1))
    ar = n_ar * 2.0 * t_loc * D * BF16 * (tp - 1) / tp
    a2a = 0.0
    if cfg.family == "moe":
        dirs = 2 * (3 if kind == "train" else 1)
        a2a = dirs * t_loc * cfg.experts_per_token * cfg.capacity_factor * D * BF16
        # TP combine of expert outputs (psum)
        a2a += dirs * t_loc * cfg.experts_per_token * cfg.capacity_factor * D * F32
    coll = ag + rs + ar + a2a
    return CellModel(
        flops_chip=flops_chip, hbm_chip=hbm_chip, coll_chip=coll,
        detail=dict(mm_flops=mm, attn_flops=attn, bytes_w=bytes_w,
                    bytes_opt=bytes_opt, bytes_act=bytes_act,
                    bytes_logits=bytes_logits, bytes_kv=bytes_kv,
                    ag=ag, rs=rs, ar=ar, a2a=a2a, tokens=tokens))
