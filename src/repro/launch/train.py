"""End-to-end training driver (example: train a ~100M backbone for N steps).

Single-host by default (reduced configs); the same step builder lowers onto
the production mesh.  Fault tolerance: async checkpoints + resume (a SIGKILL
mid-run restarts from the latest complete step), data-stream cursor included
in the checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch yi_6b --steps 200 \
      --d-model 512 --layers 8 --seq 256 --batch 16 --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLMStream
from repro.launch.mesh import make_host_mesh
from repro.launch.shapes import ShapeSpec
from repro.launch.steps import build_train_step
from repro.optim import OptConfig, init_opt_state
from repro.models import init_params
from repro.parallel.sharding import HOST_RULES, mesh_context


def scaled_config(arch: str, d_model: int, layers: int):
    """~100M-scale variant of an assigned architecture family."""
    base = get_config(arch)
    heads = max(4, d_model // 128)
    kv = max(1, heads * base.num_kv_heads // max(base.num_heads, 1)) \
        if base.num_heads else 0
    kw = dict(num_layers=layers, d_model=d_model, vocab_size=8192,
              remat=False)
    if base.num_heads:
        kw.update(num_heads=heads, num_kv_heads=max(1, kv),
                  head_dim=d_model // heads, d_ff=int(d_model * 8 / 3) // 16 * 16)
    if base.family == "moe":
        kw.update(num_experts=8, experts_per_token=2,
                  d_ff=int(d_model * 2) // 16 * 16)
    if base.family == "hybrid":
        kw.update(attn_every=max(2, layers // 3), ssm_head_dim=32)
    if base.family == "audio":
        kw.update(encoder_layers=max(2, layers // 2), encoder_seq=128)
    return dataclasses.replace(base, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config instead of --d-model/--layers")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else scaled_config(args.arch, args.d_model, args.layers))
    n_params = cfg.param_count()
    print(f"arch={cfg.name} family={cfg.family} params~{n_params/1e6:.1f}M")

    mesh = make_host_mesh()
    shape = ShapeSpec("train", "train", args.seq, args.batch)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)

    stream = SyntheticLMStream(cfg.vocab_size, args.seq, args.batch, seed=0)

    with mesh_context(mesh, HOST_RULES):
        step_fn, _ = build_train_step(cfg, mesh, HOST_RULES, shape, opt_cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)

        start = 0
        mgr = CheckpointManager(args.ckpt) if args.ckpt else None
        if mgr is not None:
            try:
                start, payload = mgr.restore_latest()
                params, opt_state = payload["params"], payload["opt"]
                stream.load_state_dict(payload["stream"])
                print(f"resumed from step {start}")
            except FileNotFoundError:
                pass

        t0 = time.time()
        for step in range(start + 1, args.steps + 1):
            batch = stream.next_batch()
            if cfg.family == "audio":
                rng = np.random.default_rng(step)
                batch["frames"] = rng.standard_normal(
                    (args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.2
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == 1:
                loss = float(metrics["loss"])
                print(f"step {step:5d}  loss {loss:7.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):7.3f}  "
                      f"{(time.time()-t0):6.1f}s", flush=True)
            if mgr is not None and step % args.ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt_state,
                                "stream": stream.state_dict()})
        if mgr is not None:
            mgr.save(args.steps, {"params": params, "opt": opt_state,
                                  "stream": stream.state_dict()}, blocking=True)
    print("done")


if __name__ == "__main__":
    main()
