"""Roofline report: aggregate the dry-run artifacts into EXPERIMENTS.md tables.

Terms (per-chip seconds per step), TPU v5e constants:
  compute_s    = FLOPs_chip / 197e12        (bf16 peak)
  memory_s     = HBM_bytes_chip / 819e9
  collective_s = collective_bytes_chip / 50e9

Sources: compute/memory from the analytic program model
(``repro.launch.analytic`` — XLA:CPU cost_analysis counts scan bodies once,
see §Dry-run caveat), collective from the loop-aware parse of the compiled
HLO (``repro.launch.hlo_analysis``).  ``useful`` = MODEL_FLOPS /
(program FLOPs x chips): how much of the compiled compute is the 6·N·D /
2·N·D ideal.  ``roofline frac`` = ideal-program time / dominant-term time.

Usage: python -m repro.launch.roofline [--mesh pod] [--suffix _cs] [--md out]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str, mesh: str, suffix: str = ""):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, f"*__{mesh}{suffix}.json"))):
        base = os.path.basename(f)[:-len(".json")]
        if suffix == "" and base.endswith("_cs"):
            continue
        recs.append(json.load(open(f)))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return recs


def analyze(r: dict) -> dict | None:
    if r["status"] != "ok":
        if r["status"] == "skipped":
            return dict(arch=r["arch"], shape=r["shape"], skipped=True,
                        why=r.get("why", ""))
        return None
    n = r["n_chips"]
    a = r["analytic"]
    coll_b = r["collective_bytes"]["total"]
    terms = dict(
        compute_s=a["flops_chip"] / PEAK_FLOPS,
        memory_s=a["hbm_chip"] / HBM_BW,
        collective_s=coll_b / ICI_BW,
    )
    dom = max(terms, key=terms.get)
    ideal_s = r["model_flops"] / n / PEAK_FLOPS
    bound_s = max(terms.values())
    useful = r["model_flops"] / max(a["flops_chip"] * n, 1.0)
    return dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"], n_chips=n,
                skipped=False, **terms, dominant=dom, useful=useful,
                roofline_frac=min(ideal_s / max(bound_s, 1e-30), 1.0),
                model_flops=r["model_flops"], coll=r["collective_bytes"],
                coll_est=a["coll_chip"], hlo_flops=r["hlo_flops"],
                mem_bytes=r["memory"]["bytes_per_device"])


def fmt_table(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "useful (MODEL/HLO) | roofline frac | HBM/chip (GB) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a in rows:
        if a is None:
            continue
        if a.get("skipped"):
            out.append(f"| {a['arch']} | {a['shape']} | — | — | — | skipped | — | — | — |")
            continue
        out.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.3e} | "
            f"{a['memory_s']:.3e} | {a['collective_s']:.3e} | "
            f"{a['dominant'].replace('_s','')} | {a['useful']:.2f} | "
            f"{a['roofline_frac']:.3f} | {a['mem_bytes']/1e9:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=RESULTS_DIR)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--suffix", default="")
    args = ap.parse_args()
    rows = [analyze(r) for r in load(args.dir, args.mesh, args.suffix)]
    rows = [r for r in rows if r]
    live = [r for r in rows if not r.get("skipped")]
    print(fmt_table(rows, f"Roofline — {args.mesh} mesh"))
    print()
    worst = sorted(live, key=lambda a: a["roofline_frac"])[:6]
    print("worst roofline fraction:")
    for a in worst:
        print(f"  {a['arch']} x {a['shape']}: {a['roofline_frac']:.4f} "
              f"(dom {a['dominant']})")
    collb = sorted(live, key=lambda a: -(a["collective_s"] /
                                         max(max(a["compute_s"], a["memory_s"]), 1e-30)))[:6]
    print("most collective-bound (coll / max(other terms)):")
    for a in collb:
        print(f"  {a['arch']} x {a['shape']}: "
              f"{a['collective_s']/max(max(a['compute_s'],a['memory_s']),1e-30):.2f} "
              f"coll={a['collective_s']:.2e}s")


if __name__ == "__main__":
    main()
