"""Serving driver: ReXCam-filtered cross-camera analytics on live streams.

Replays a calibrated camera-network simulation through the ServingEngine via
the ``repro.api`` facade: one SearchPolicy decides which (camera, frame)
pairs reach the inference plane; the engine vector-admits all queries at
once, batches and embeds the deduplicated frames (feature oracle or a smoke
backbone), ranks with the re-id kernel semantics, and replays the FrameStore
ring buffer when a query escalates to phase 2.

  PYTHONPATH=src python -m repro.launch.serve --queries 8 --steps 600

``--shards k`` runs the sharded fleet instead (shard_map over the query
axis, trace-identical to the single engine) and prints per-shard cost.  On
a CPU host, fake the devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \
    PYTHONPATH=src python -m repro.launch.serve --queries 8 --shards 4

``--transport fake --rtt 0.01 --prefetch`` (fleet only) routes every
owner-shard gallery fetch through a ``FakeRpcTransport`` with injected
latency/jitter/drop and turns on the double-buffered speculative prefetch;
the transport-plane line prints remote fetches, prefetch hits/waste,
retries, timeouts and dead peers.

``--recalibrate`` closes the paper's §6 drift loop: a
``RecalibrationController`` watches the engine's live rescue matrix and
hot-swaps a model re-profiled from the recent window when the drift score
trips the trigger (knobs: ``--drift-threshold``, ``--recal-cooldown``,
``--recal-window``); swap events and the final model epoch are printed.
"""
from __future__ import annotations

import argparse
import time

from repro import api as rexcam
from repro.core import build_gallery, duke_like_network, simulate_network
from repro.core.features import FeatureParams, make_features


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--s-thresh", type=float, default=0.05)
    ap.add_argument("--t-thresh", type=float, default=0.02)
    ap.add_argument("--scheme", default="rexcam",
                    choices=["rexcam", "all", "geo", "spatial_only"])
    ap.add_argument("--shards", type=int, default=None,
                    help="partition the query axis over this many devices "
                         "(default: single-process engine)")
    ap.add_argument("--gallery", default="auto",
                    choices=["auto", "local", "sharded"],
                    help="embedding plane: auto (local for one engine, "
                         "fleet-shared sharded store for --shards), local "
                         "(replicated baseline) or sharded (fleet only)")
    ap.add_argument("--topk", type=int, default=1,
                    help="surface the k best (value, cam, frame) candidate "
                         "bands per round in trace records (argmax path "
                         "unchanged)")
    ap.add_argument("--topk-rerank", action="store_true",
                    help="§5.2 top-k confidence re-ranking: passing bands "
                         "vote by summed score per camera and the match "
                         "re-anchors to the winning camera's best band "
                         "(bit-identical to argmax at --topk 1)")
    ap.add_argument("--tile-grid", type=int, default=0,
                    help="sub-frame spatial admission: T > 0 profiles per "
                         "camera-pair entry-region masks on a TxT tile grid "
                         "and serves through the tile-masked kernel, "
                         "scoring only detections inside admitted tiles")
    ap.add_argument("--transport", default="none",
                    choices=["none", "inproc", "fake"],
                    help="gallery fetch plane (fleet only): none (direct "
                         "zero-copy reads), inproc (same behavior through "
                         "the Transport contract, counters tick) or fake "
                         "(FakeRpcTransport with --rtt/--jitter/--drop "
                         "injected per fetch, timeout/retry/backoff)")
    ap.add_argument("--rtt", type=float, default=0.005,
                    help="injected one-way fetch latency in seconds "
                         "(--transport fake)")
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="uniform extra latency bound in seconds "
                         "(--transport fake)")
    ap.add_argument("--drop", type=float, default=0.0,
                    help="per-attempt drop probability; dropped fetches "
                         "time out and retry with backoff (--transport fake)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffered speculative fetch: issue round "
                         "N+1's predicted gallery reads at the end of round "
                         "N so transport latency hides behind compute")
    ap.add_argument("--recalibrate", action="store_true",
                    help="close the §6 drift loop: watch the live rescue "
                         "matrix and hot-swap a re-profiled model when the "
                         "drift score trips the trigger")
    ap.add_argument("--drift-threshold", type=float, default=0.1,
                    help="recalibration trigger: max drift_score to trip at")
    ap.add_argument("--recal-cooldown", type=int, default=240,
                    help="min ticks between model swaps (hysteresis)")
    ap.add_argument("--recal-window", type=int, default=1200,
                    help="sliding re-profile window (recent steps)")
    args = ap.parse_args()

    net = duke_like_network()
    vis = simulate_network(net, 1500, 3000, seed=0)
    gal, _ = build_gallery(vis, 24)
    model = rexcam.profile(vis, time_limit=2000, tile_grid=args.tile_grid)
    feats, _ = make_features(vis, 1500, FeatureParams())
    q_vids, _ = rexcam.make_queries(vis, args.queries, seed=1)

    policy = rexcam.SearchPolicy(scheme=args.scheme, s_thresh=args.s_thresh,
                                 t_thresh=args.t_thresh)
    recal = rexcam.RecalibrationPolicy(
        drift_threshold=args.drift_threshold, cooldown=args.recal_cooldown,
        window=args.recal_window) if args.recalibrate else None
    if args.transport == "fake":
        transport = rexcam.FakeRpcTransport(
            default=rexcam.FaultProfile(latency=args.rtt, jitter=args.jitter,
                                        drop=args.drop),
            timeout=max(4 * (args.rtt + args.jitter), 1.0))
    else:
        transport = None if args.transport == "none" else args.transport
    eng = rexcam.serve(model, embed_fn=lambda x: x, policy=policy,
                       geo_adj=net.geo_adjacent, shards=args.shards,
                       gallery=args.gallery, topk=args.topk,
                       transport=transport, prefetch=args.prefetch,
                       tile_grid=args.tile_grid,
                       topk_rerank=args.topk_rerank,
                       recalibrate=recal,
                       visit_source=rexcam.visits_window_source(vis)
                       if args.recalibrate else None)
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))

    if args.tile_grid > 0:
        from repro.core.simulate import tile_index
        vis_tiles = tile_index(vis.tile_xy, args.tile_grid)
    wall0 = time.time()
    matches = 0
    for t in range(t0, min(t0 + args.steps, vis.horizon)):
        frames = {}
        tiles = {}
        for c in range(net.n_cams):
            vids = gal[c, t]
            vids = vids[vids >= 0]
            if len(vids):
                frames[c] = feats[vids]
                if args.tile_grid > 0:
                    tiles[c] = vis_tiles[vids]
        if args.tile_grid > 0:
            eng.ingest(frames, tiles)
        else:
            eng.ingest(frames)
        stats = eng.tick()
        matches += stats["matches"]
    wall = time.time() - wall0

    # two cost conventions (don't mix them): admitted_steps is per-query
    # camera-steps (comparable with the tracker / policy_sweep); the frame
    # counts are the serving plane's deduplicated inference load
    naive_steps = args.steps * net.n_cams * len(q_vids)
    naive_frames = args.steps * net.n_cams
    print(f"steps={args.steps} queries={args.queries} scheme={policy.scheme}")
    print(f"admission: {eng.admitted_steps} camera-steps "
          f"(naive all-camera: {naive_steps}; "
          f"savings {naive_steps/max(eng.admitted_steps,1):.1f}x)")
    print(f"inference plane: {eng.unique_frames} unique frames "
          f"({eng.frames_processed} embedded + {eng.cache_hits} cache-hot; "
          f"dedup {eng.admitted_steps/max(eng.unique_frames,1):.1f}x; "
          f"naive per-camera: {naive_frames}; "
          f"savings {naive_frames/max(eng.frames_processed,1):.1f}x)")
    if args.tile_grid > 0:
        TT = args.tile_grid * args.tile_grid
        base_tiles = TT * eng.admitted_steps
        print(f"spatial plane [T={args.tile_grid}]: {eng.admitted_tiles} "
              f"admitted tiles of {base_tiles} camera-granular "
              f"(pixel-load savings "
              f"{base_tiles/max(eng.admitted_tiles,1):.1f}x; "
              f"{eng.unique_tiles} deduplicated of "
              f"{TT * eng.unique_frames})")
    print(f"matches flagged: {matches} "
          f"(replay rescues: {sum(q.rescued for q in eng.queries.values())}, "
          f"replay misses past retention: {eng.replay_misses})")
    print(f"frame-store residency: {eng.store.memory_frames()} frames "
          f"(retention {eng.cfg.retention}s — paper §5.3 'last few minutes')")
    g = eng.gallery_report()
    print(f"gallery plane [{g['kind']}]: {g['cached']} blocks resident "
          f"({g['bytes']} bytes), {g['hits']} hits / {g['misses']} misses, "
          f"{g['evictions']} evictions")
    if args.transport != "none" or args.prefetch:
        c = eng.gallery.counters()
        kind = getattr(getattr(eng.gallery, "transport", None), "kind",
                       "local")
        print(f"transport plane [{kind}]: {c['remote_fetches']} remote "
              f"fetches ({c['prefetch_hits']} served by prefetch, "
              f"{c['prefetch_wasted']} wasted speculations), "
              f"{c['retries']} retries, {c['timeouts']} timeouts, "
              f"{c.get('dead_peers', 0)} dead peers")
    print(f"wall: {wall:.2f}s ({args.steps/max(wall,1e-9):.0f} steps/s)")
    if args.recalibrate:
        ev = eng.recal.events
        print(f"recalibration [epoch {eng.model_epoch}]: {len(ev)} swaps, "
              f"{len(eng.recal.polls)} polls "
              f"(threshold {args.drift_threshold}, "
              f"cooldown {args.recal_cooldown}, window {args.recal_window})")
        for e in ev:
            print(f"  t={e['t']}: epoch {e['epoch']} "
                  f"(score {e['score']:.2f}, {e['rescues']} rescues, "
                  f"re-profiled {e['visits']} visits in "
                  f"[{e['window'][0]}, {e['window'][1]}))")
    if args.shards is not None:
        # per-shard demand is shard-LOCAL dedup: a frame two shards both
        # want counts once per shard here but once in the engine totals;
        # owned_frames is each worker's slice of the fleet-global dedup
        # (sums to the engine total when the gallery is sharded)
        print(f"fleet: {eng.n_shards} shards (data axis), "
              f"{eng.rebalances} rebalances")
        per_worker = g.get("per_worker", {})
        for row in eng.shard_report():
            state = "live" if row["alive"] else "lost"
            gw = per_worker.get(row["worker"])
            gal = (f" gallery={gw['blocks']} blocks/{gw['bytes']}B "
                   f"({gw['cameras']} cams)" if gw else "")
            print(f"  {row['worker']} [{state}]: {row['queries']} queries, "
                  f"admitted_steps={row['admitted_steps']} "
                  f"unique_frames={row['unique_frames']} "
                  f"owned_frames={row['owned_frames']} "
                  f"query_rounds={row['query_rounds']}{gal}")
    for qid, q in eng.queries.items():
        lag = max(eng.t - 1 - q.f_curr, 0)
        state = "done" if q.done else f"tracking (phase {q.phase}, lag {lag}s)"
        print(f"  query {qid}: {len(q.matches)} matches, {state}")


if __name__ == "__main__":
    main()
