"""Step builders: train / prefill / decode with full sharding specs.

Each builder returns ``(fn, in_shardings, out_shardings, abstract_inputs)``
ready for ``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*abstract)``
— the single code path shared by the dry-run, the train driver and the
serving driver.  Sharding specs are derived from the models' logical axis
trees through the active rule table, so swapping meshes is a rules change.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.shapes import (
    SHAPES, ShapeSpec, batch_logical_axes, decode_token_specs, sds,
    train_batch_specs,
)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw_update, init_opt_state
from repro.parallel.sharding import AxisRules, logical_to_spec
from repro.perf import get_flags

SERVE_HBM_BUDGET = 8e9  # bytes/chip for weight-stationary (no-FSDP) serving


def _serve_param_rules(cfg: ModelConfig, rules: AxisRules) -> AxisRules:
    """Weight-stationary serving (PerfFlags.serve_params_replicated): drop the
    FSDP axis when the per-chip TP shard fits — removes the per-token weight
    all-gathers that dominate the decode collective term."""
    if not get_flags().serve_params_replicated:
        return rules
    n_total = cfg.param_count()
    if cfg.family == "moe":
        n_exp = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.d_ff
        n_dense = n_total - n_exp        # experts stay EP-sharded over data
        per_chip = n_dense * 4 / 16
    else:
        per_chip = n_total * 4 / 16
    if per_chip > SERVE_HBM_BUDGET:
        return rules                     # 104B-class: keep FSDP-serving
    return AxisRules({**rules.rules, "fsdp": ()})

REPL = P()


def _tree_shardings(mesh: Mesh, rules: AxisRules, logical_tree: Any) -> Any:
    def is_ax(x):
        return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)

    return jax.tree.map(
        lambda ax: NamedSharding(mesh, logical_to_spec(ax, rules)),
        logical_tree, is_leaf=is_ax)


def opt_state_axes(param_axes):
    return {"m": param_axes, "v": param_axes, "step": ()}


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh: Mesh, rules: AxisRules,
                     shape: ShapeSpec, opt_cfg: OptConfig | None = None,
                     causal_skip: bool = False):
    opt_cfg = opt_cfg or OptConfig()
    import dataclasses as _dc
    flags = get_flags()
    bf16_params = flags.bf16_params
    if bf16_params:
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    if flags.pad_vocab:
        cfg = cfg.with_padded_vocab()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.lm_loss, has_aux=True)(params, batch, cfg, causal_skip=causal_skip)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    p_ax = M.param_logical_axes(cfg)
    p_sh = _tree_shardings(mesh, rules, p_ax)
    o_sh = {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, REPL)}
    if bf16_params:
        o_sh["master"] = p_sh
    b_sh = _tree_shardings(mesh, rules, batch_logical_axes(cfg))
    m_sh = NamedSharding(mesh, REPL)

    params_abs = M.abstract_params(cfg)
    opt_abs = jax.eval_shape(
        lambda p: init_opt_state(p, master_weights=bf16_params), params_abs)
    batch_abs = train_batch_specs(cfg, shape)
    metrics_sh = jax.tree.map(lambda _: m_sh,
                              {"loss": 0, "ntok": 0, "moe_aux": 0, "moe_z": 0,
                               "grad_norm": 0, "lr": 0})

    jitted = jax.jit(train_step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, metrics_sh),
                     donate_argnums=(0, 1))
    return jitted, (params_abs, opt_abs, batch_abs)


# ---------------------------------------------------------------------------
# prefill / decode (serve_step)
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, mesh: Mesh, rules: AxisRules,
                       shape: ShapeSpec):
    max_len = shape.seq_len
    if get_flags().pad_vocab:
        cfg = cfg.with_padded_vocab()

    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, max_len=max_len)

    p_rules = _serve_param_rules(cfg, rules)
    p_sh = _tree_shardings(mesh, p_rules, M.param_logical_axes(cfg))
    b_sh = _tree_shardings(mesh, rules, batch_logical_axes(cfg))
    st_sh = _tree_shardings(
        mesh, rules, M.decode_state_logical_axes(cfg, seq_shard=shape.seq_shard))
    v_ax = "vocab" if cfg.shard_vocab else None
    logits_sh = NamedSharding(mesh, logical_to_spec(("batch", v_ax), rules))

    params_abs = M.abstract_params(cfg)
    batch_abs = train_batch_specs(cfg, shape)

    jitted = jax.jit(prefill_step,
                     in_shardings=(p_sh, b_sh),
                     out_shardings=(logits_sh, st_sh))
    return jitted, (params_abs, batch_abs)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, rules: AxisRules,
                      shape: ShapeSpec):

    if get_flags().pad_vocab:
        cfg = cfg.with_padded_vocab()

    def decode_step(params, state, token):
        return M.decode_step(params, state, token, cfg)

    p_rules = _serve_param_rules(cfg, rules)
    p_sh = _tree_shardings(mesh, p_rules, M.param_logical_axes(cfg))
    st_ax = M.decode_state_logical_axes(cfg, seq_shard=shape.seq_shard)
    st_sh = _tree_shardings(mesh, rules, st_ax)
    b_ax = None if shape.seq_shard else "batch"   # long_500k: batch=1 replicated
    tok_sh = NamedSharding(mesh, logical_to_spec((b_ax,), rules))
    v_ax = "vocab" if cfg.shard_vocab else None
    logits_sh = NamedSharding(mesh, logical_to_spec((b_ax, v_ax), rules))

    params_abs = M.abstract_params(cfg)
    state_abs = jax.eval_shape(
        lambda: M.init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                    seq_shard=shape.seq_shard))
    token_abs = decode_token_specs(cfg, shape)

    jitted = jax.jit(decode_step,
                     in_shardings=(p_sh, st_sh, tok_sh),
                     out_shardings=(logits_sh, st_sh),
                     donate_argnums=(1,))
    return jitted, (params_abs, state_abs, token_abs)


def build_step(cfg: ModelConfig, mesh: Mesh, rules: AxisRules, shape_name: str,
               **kw):
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, mesh, rules, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, rules, shape)
    return build_decode_step(cfg, mesh, rules, shape)
