"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing never touches jax
device state — required because the dry-run must set
``xla_force_host_platform_device_count`` before jax initializes.
"""
from __future__ import annotations

from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for tests/examples (everything replicated)."""
    return make_mesh((1, 1), ("data", "model"))


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for subprocess sharding tests (requires host-device flag)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
