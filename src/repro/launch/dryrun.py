import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the step function + abstract inputs (``repro.launch.steps``),
  3. ``.lower().compile()`` — sharding or memory bugs surface HERE,
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs/bytes for the roofline),
     and the collective-bytes tally parsed from the optimized HLO,
  5. writes a JSON artifact under ``results/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch yi_6b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
"""
import argparse
import dataclasses as _dc


def dataclassesdict(x):
    return _dc.asdict(x)
import json
import re
import time
import traceback

import jax
import numpy as np

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2)

from repro.configs import ARCH_IDS, get_config
from repro.perf import PerfFlags, perf_flags
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_supported
from repro.launch.steps import build_step
from repro.parallel.sharding import (MULTI_POD_RULES, SINGLE_POD_RULES,
                                     mesh_context, pure_fsdp_rules)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip usable)

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    Counts each op once via its result shape (the payload that crosses the
    interconnect at least once); ops inside while-loop bodies are multiplied
    by the loop trip count when it is statically inferable from the HLO
    (scan-lowered loops carry ``trip_count`` in backend_config comments —
    conservatively, we use static counts parsed from induction bounds when
    present, else 1).
    """
    totals: dict[str, float] = {}
    # map loop body computation name -> trip count (best effort)
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or "-done" in m.group(0):
            continue
        kind = m.group(1)
        # result shape is the lhs type annotation: e.g. "%ag = f32[16,1024]{..} all-gather(...)"
        lhs = line.split("=", 1)
        if len(lhs) < 2:
            continue
        shapes = _SHAPE_RE.findall(lhs[1].split(m.group(0))[0])
        nbytes = 0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[kind] = totals.get(kind, 0.0) + nbytes
    totals["total"] = sum(totals.values())
    return totals


def while_trip_counts(hlo_text: str) -> list[int]:
    """Trip counts of while loops when XLA annotated them."""
    return [int(x) for x in re.findall(r'trip_count["\s:=]+(\d+)', hlo_text)]


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, compile_: bool = True,
             causal_skip: bool = False, out_dir: str | None = None,
             flags: PerfFlags | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    flags = flags or PerfFlags(causal_skip=causal_skip)
    causal_skip = flags.causal_skip
    supported, why = cell_supported(cfg, shape_name)
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "family": cfg.family, "status": "skipped", "why": why,
           "causal_skip": causal_skip, "tag": tag,
           "flags": dataclassesdict(flags)}
    if not supported:
        return _finish(rec, out_dir)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = MULTI_POD_RULES if multi_pod else SINGLE_POD_RULES
    if (flags.dense_pure_fsdp and SHAPES[shape_name].kind == "train"
            and cfg.family in ("dense", "vlm")):
        rules = pure_fsdp_rules(rules)
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        with perf_flags(flags), mesh_context(mesh, rules):
            jitted, abstract = build_step(cfg, mesh, rules, shape_name,
                                          **({"causal_skip": True}
                                             if causal_skip and shape_name == "train_4k"
                                             else {}))
            lowered = jitted.lower(*abstract)
            rec["lower_s"] = round(time.time() - t0, 1)
            if not compile_:
                rec["status"] = "lowered"
                return _finish(rec, out_dir)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        from repro.launch.hlo_analysis import collective_schedule
        coll = collective_schedule(hlo)        # loop-aware (trip-count x)
        coll_flat = collective_bytes(hlo)      # naive (loop bodies once)
        trips = while_trip_counts(hlo)
        from repro.launch.analytic import analytic_cell
        with perf_flags(flags):
            amodel = analytic_cell(cfg, shape_name, multi_pod=multi_pod,
                                   causal_skip=causal_skip)

        flops = float(cost.get("flops", 0.0))
        bytes_hbm = float(cost.get("bytes accessed", 0.0))
        rec.update(
            status="ok",
            n_chips=n_chips,
            hlo_flops=flops,
            hlo_bytes=bytes_hbm,
            collective_bytes=coll,
            collective_bytes_flat=coll_flat,
            analytic=dict(flops_chip=amodel.flops_chip,
                          hbm_chip=amodel.hbm_chip,
                          coll_chip=amodel.coll_chip, **amodel.detail),
            while_trip_counts=trips[:32],
            memory=dict(
                bytes_per_device=getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0),
                temp=getattr(mem, "temp_size_in_bytes", 0),
                args=getattr(mem, "argument_size_in_bytes", 0),
                output=getattr(mem, "output_size_in_bytes", 0),
                alias=getattr(mem, "alias_size_in_bytes", 0),
                generated_code=getattr(mem, "generated_code_size_in_bytes", 0),
            ),
            model_flops=model_flops(cfg, shape_name),
        )
        # roofline terms in per-chip seconds.  cost_analysis() describes the
        # per-device SPMD module (shapes in the optimized HLO are local
        # shards), so the values are already per-chip — no further division.
        rec["roofline"] = dict(
            compute_s=flops / PEAK_FLOPS,
            memory_s=bytes_hbm / HBM_BW,
            collective_s=coll["total"] / ICI_BW,
        )
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["roofline"]["dominant"] = dom
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
              f"flops={flops:.3e} bytes={bytes_hbm:.3e} "
              f"coll={coll['total']:.3e} dom={dom}")
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{arch} x {shape_name} x {mesh_name}] FAILED: {rec['error']}")
    return _finish(rec, out_dir)


def model_flops(cfg, shape_name: str) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for train, 2·N_active·D for inference."""
    s = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        return 6.0 * n_active * tokens
    if s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * s.global_batch  # decode: one token per request


def _finish(rec: dict, out_dir: str | None):
    out_dir = out_dir or RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    suffix = rec.get("tag") or ("_cs" if rec.get("causal_skip") else "")
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    slim = {k: v for k, v in rec.items() if k != "traceback"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)
    if rec.get("traceback"):
        with open(path + ".err", "w") as f:
            f.write(rec["traceback"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--causal-skip", action="store_true",
                    help="balanced-causal attention schedule (perf variant)")
    ap.add_argument("--opt", action="store_true",
                    help="all beyond-paper perf flags on; artifacts get _opt")
    ap.add_argument("--flags", default=None,
                    help="comma list of PerfFlags fields to enable")
    ap.add_argument("--tag", default=None, help="artifact filename suffix")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.opt:
        flags = PerfFlags.all_on()
        tag = args.tag or "_opt"
    elif args.flags:
        flags = PerfFlags(**{k: True for k in args.flags.split(",")})
        tag = args.tag or ("_" + "-".join(sorted(args.flags.split(","))))
    else:
        flags = PerfFlags(causal_skip=args.causal_skip)
        tag = args.tag or ("_cs" if args.causal_skip else "")

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.skip_existing:
                    suffix = "_cs" if args.causal_skip else ""
                    p = os.path.join(RESULTS_DIR,
                                     f"{arch}__{shape}__{'multipod' if mp else 'pod'}{suffix}.json")
                    if os.path.exists(p):
                        st = json.load(open(p)).get("status")
                        if st in ("ok", "skipped"):
                            continue
                rec = run_cell(arch, shape, mp, compile_=not args.no_compile,
                               flags=flags, tag=tag)
                n_ok += rec["status"] in ("ok", "skipped", "lowered")
                n_fail += rec["status"] == "error"
    print(f"done: {n_ok} ok/skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
