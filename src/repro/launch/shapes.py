"""Assigned input-shape registry + abstract input specs per (arch, shape).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation — exactly what
``jax.jit(...).lower()`` needs for the dry-run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int
    seq_shard: bool = False  # long-context: shard the KV/cache sequence axis


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, seq_shard=True),
}

# long_500k needs a sub-quadratic path: run for SSM/hybrid, skip for pure
# full-attention archs (DESIGN.md §4).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    s = SHAPES[shape]
    if s.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, ("skipped: pure full-attention arch has no sub-quadratic "
                       "path at 512k (DESIGN.md §4)")
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, s: ShapeSpec) -> dict:
    B, S = s.global_batch, s.seq_len
    batch = {"tokens": sds((B, S), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.mrope:
        batch["positions"] = sds((3, B, S), jnp.int32)
    return batch


def batch_logical_axes(cfg: ModelConfig) -> dict:
    ax = {"tokens": ("batch", "seq")}
    if cfg.family == "audio":
        ax["frames"] = ("batch", None, "embed")
    if cfg.mrope:
        ax["positions"] = (None, "batch", "seq")
    return ax


def decode_token_specs(cfg: ModelConfig, s: ShapeSpec) -> jax.ShapeDtypeStruct:
    return sds((s.global_batch,), jnp.int32)
