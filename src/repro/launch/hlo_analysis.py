"""Loop-aware HLO analysis: collective bytes with while-loop trip counts.

XLA:CPU's ``cost_analysis()`` counts while-loop bodies ONCE (scan-heavy
programs are undercounted), but the optimized HLO annotates loops with
``backend_config={"known_trip_count":{"n":...}}``.  This parser

  1. splits the module into computations,
  2. finds every ``while`` op, its body computation and trip count,
  3. propagates multipliers through the call/fusion/loop graph,
  4. sums collective payload bytes x multiplier.

The result is the *actual per-step collective schedule* of the compiled
program — the roofline's collective term.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

def _header_name(stripped: str) -> str | None:
    """Computation-definition header: ``[ENTRY] %name (params...) -> type {``.

    Params may nest parens (tuple types), so no full-regex — a header is a
    line that ends with '{', has a '->' return annotation, and whose text
    before the first '(' is just the (possibly ENTRY-prefixed) name."""
    if not stripped.endswith("{") or "->" not in stripped:
        return None
    head = stripped.split("(", 1)[0].strip()
    if "=" in head or not head:
        return None
    parts = head.split()
    if parts[0] == "ENTRY" and len(parts) > 1:
        return parts[1].lstrip("%")
    if len(parts) == 1:
        return parts[0].lstrip("%")
    return None
_SHAPE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_WHILE = re.compile(r"while\(.*?\).*?body=%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_COLLECTIVE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_module(hlo: str):
    """Returns computations: name -> list[instruction line]."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        name = _header_name(stripped)
        if name is not None:
            cur = name
            comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps


def collective_schedule(hlo: str) -> dict:
    """Loop-aware collective byte totals {kind: bytes, 'total': ..., 'ops': n}."""
    comps = parse_module(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.strip().startswith("ENTRY"):
            entry = _header_name(line.strip())
            if entry:
                break
    # edges: computation -> [(callee, multiplier)]
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            wm = _WHILE.search(ln)
            if wm:
                tm = _TRIP.search(ln)
                trips = int(tm.group(1)) if tm else 1
                edges[name].append((wm.group(1), trips))
                continue
            cm = _CALLS.search(ln)
            if cm:
                for callee in re.split(r",\s*", cm.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        edges[name].append((callee, 1))

    # propagate multipliers from entry
    mult: dict[str, int] = defaultdict(int)
    start = entry if entry in comps else max(comps, key=lambda c: len(comps[c]))
    stack = [(start, 1)]
    seen_pairs = set()
    while stack:
        node, m = stack.pop()
        mult[node] = max(mult[node], m) if mult[node] else m
        mult[node] = m if mult[node] < m else mult[node]
        for callee, k in edges.get(node, []):
            key = (node, callee, m * k)
            if key in seen_pairs:
                continue
            seen_pairs.add(key)
            stack.append((callee, m * k))

    totals: dict[str, float] = defaultdict(float)
    n_ops = 0
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for ln in lines:
            cm = _COLLECTIVE.search(ln)
            if not cm or "-done" in ln.split("=")[0]:
                continue
            lhs = ln.split("=", 1)
            if len(lhs) < 2:
                continue
            nbytes = _shape_bytes(lhs[1].split(cm.group(0))[0])
            totals[cm.group(1)] += nbytes * m
            n_ops += m
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    totals["ops"] = n_ops
    return dict(totals)
