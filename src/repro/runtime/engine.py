"""The serving engine: ReXCam admission control over the inference plane.

Per tick (one wall step over all live camera streams):

  1. ALL active queries are gathered into one batched
     ``repro.core.policy.PhaseState`` and a single vectorized
     ``policy.admit`` call (jit, policy static) produces the (Q, C)
     admission mask — the same function, windows and phase machine the
     batched offline tracker runs, so the two planes cannot drift,
  2. admitted (camera, frame) pairs are deduplicated across queries (a
     frame is detected / embedded once no matter how many queries want it —
     the fleet-scale batching win), with replay re-reads served from the
     ``FrameStore`` embedding cache so a still-retained frame is never
     embedded twice,
  3. the deduplicated embedding batch is ranked ON DEVICE: one
     ``kernels.reid_topk_masked`` pass scores every query against exactly
     its admitted galleries (camera-major order, so tie-breaking is
     bit-identical to the tracker's flat argmin) and returns
     matched / match_cam / match_emb for the whole round,
  4. match outcomes feed ``policy.advance``: matches re-anchor to phase 1;
     a query whose phase-1 windows exhaust REWINDS its cursor to f_q + 1
     and replays retained frames out of the ``FrameStore`` ring buffer with
     relaxed thresholds (§5.3) — frames evicted past the retention window
     surface as ``replay_misses`` (the cold-storage fallback the paper
     mentions).

Replay pacing follows §5.3: a lagging query consumes
``policy.replay_speed * policy.replay_skip`` content steps per wall tick
(skip mode samples 1-in-k of them inside ``admit``).  Sampled-out replay
rounds are short-circuited on the host — the content step is still charged,
but no admission/inference work is dispatched for a round whose mask is
all-False by construction.

Cost accounting reports BOTH conventions: ``admitted_steps`` counts
per-query camera-steps (comparable with the tracker's / ``policy_sweep``'s
cost), while ``unique_frames`` counts deduplicated (camera, frame) pairs
(the serving plane's actual inference load).

The engine is deliberately backbone-agnostic: ``embed_fn(frames) ->
(n, D)`` may be a smoke-scale transformer from ``repro.models`` or the
simulator's feature oracle (tests).

The device-side step bodies (``rank_advance_round``, ``advance_round`` and
``policy.admit``) are pure over the (Q,)-batched state, with batch-row
assignment indirected through ``_layout``/``self._slots`` — that is what
lets ``runtime.fleet.ShardedServingEngine`` run the SAME round code with
the query axis shard_map-partitioned over a device mesh, trace-identically
(padding rows are ``done`` and rank to (NEG_INF, -1) like the kernels'
padded slots).
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.correlation import SpatioTemporalModel
from repro.core.policy import (PhaseState, SearchPolicy, admit, advance,
                               phase_windows, replay_sampled_out)
from repro.kernels import ops as kernel_ops
from repro.kernels.reid_topk import NEG_INF
from repro.runtime.gallery import (GalleryStore, LocalGalleryStore,
                                   assemble_round_gallery, l2_normalize,
                                   pow2)
from repro.runtime.stream_store import FrameStore
from repro.runtime.transport import PrefetchPipeline

# effectively "never": the live engine terminates queries via exit_t /
# window exhaustion, not a simulation horizon
_NO_HORIZON = 2 ** 30


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-plane settings.  All *search* semantics live in ``policy`` —
    the same ``SearchPolicy`` the offline tracker takes."""

    policy: SearchPolicy = SearchPolicy()
    max_batch: int = 256
    retention: int = 600
    embed_cache: bool = True          # gallery-plane embedding cache (§5.3)
    short_circuit_skips: bool = True  # host fast path for sampled-out rounds
    # which GalleryStore backs the embedding plane: "auto" (local for the
    # single engine, the fleet-shared sharded store for the fleet),
    # "local" (replicated per-engine) or "sharded" (fleet only)
    gallery: str = "auto"
    # top-k candidate bands surfaced per query round in the trace records
    # (§5.2 confidence bands / re-ranking); the argmax match path is always
    # band 0, so topk=1 is exactly the classic engine
    topk: int = 1
    # the gallery fetch plane (runtime.transport): None keeps today's
    # direct zero-copy reads; a Transport instance routes every fetch of an
    # owner-resident block through it (fleet + sharded gallery only)
    transport: Any = None
    # double-buffered speculative fetch: at the end of round N the engine
    # issues async fetches for round N+1's predicted admitted blocks, so
    # transport latency hides behind the rank pass (misspeculation falls
    # back to the blocking fetch, exactly accounted)
    prefetch: bool = False
    # cross-query object-level consolidation: rank the whole round through
    # the segment-ID kernel (one ``reid_topk_segments`` call over the
    # fleet-global ``RoundPlan``, content frames relabeled to compact
    # per-round segment ids).  False keeps the per-frame reference path —
    # the two are trace-identical (the relabeling is injective), which the
    # consolidation differential pins
    consolidate: bool = True
    # sub-frame spatial admission (CrossRoI-style): T > 0 refines camera
    # admission to a T x T tile grid — the round ranks through the
    # tile-masked ``reid_topk_tiles`` kernel over the fused (camera, tile)
    # admission ``policy.admit_tiles`` builds from the model's learned
    # ``tile_admit`` tensor.  0 (default) keeps camera-granular admission;
    # a model without tile data gets an all-tiles-admitted tensor, which is
    # trace-identical to the camera path (the tile differential's oracle)
    tile_grid: int = 0
    # §5.2 top-k confidence re-ranking: the k best candidate bands vote by
    # summed passing score per camera and the match re-anchors to the
    # winning camera's best band.  Bit-identical to the argmax path at
    # topk=1 (pinned by the k=1 equivalence regression)
    topk_rerank: bool = False


@dataclasses.dataclass
class QueryState:
    qid: int
    feat: np.ndarray
    c_q: int
    f_q: int
    f_curr: int            # content frame the search cursor is on
    phase: int = 1
    done: bool = False
    matches: list = dataclasses.field(default_factory=list)
    rescued: int = 0       # matches made during replay (phase >= 2)
    replay_credit: float = 0.0  # fractional replay-round carry (ff pacing)
    submit_t: int = 0      # engine wall tick the query was submitted at
    first_match_t: int = -1  # wall tick of the first confirmed match (delay)
    # tile mode only: the fused-cell tile of the last confirmed match (-1
    # before the first match — the anchor detection carries no tile).  A
    # LEARNED tile model narrows the self-camera follow window to this
    # tile's 3x3 neighborhood (policy.tile_follow_mask)
    tile_q: int = -1


@partial(jax.jit, static_argnames=("policy",))
def _admit_jit(model, policy: SearchPolicy, state: PhaseState, geo_adj=None):
    return admit(model, policy, state, geo_adj)


@partial(jax.jit, static_argnames=("policy",))
def _admit_tiles_jit(model, policy: SearchPolicy, state: PhaseState,
                     geo_adj=None, tile_q=None):
    from repro.core.policy import admit_tiles
    return admit_tiles(model, policy, state, geo_adj, tile_q)


def _rank_outcome(sv, si, gallery, gal_cam, gal_frame, match_thresh,
                  n_cams: int = 0, topk_rerank: bool = False):
    """Shared post-kernel half of every ranking path: convert the (Q, k)
    score/index bands into the control plane's match outcome.  The best
    (band-0) score converts back to the cosine distance the threshold is
    applied to; unmatched rows carry cam 0 and an arbitrary embedding row;
    padded / fully-masked slots come back as (NEG_INF, -1, -1, -1) in the
    bands, exactly like the kernels.

    ``topk_rerank`` (§5.2): instead of committing to band 0's camera, the
    bands that pass the match threshold vote by summed score per camera and
    the match re-anchors to the winning camera's best band.  ``matched`` is
    unchanged (the bands are score-sorted, so "any band passes" == "band 0
    passes"), and at k=1 only band 0 can vote — the whole path is
    bit-identical to the argmax path, which the k=1 equivalence regression
    pins."""
    valid = si >= 0
    idx = jnp.maximum(si, 0)
    topk_cam = jnp.where(valid, gal_cam[idx], -1).astype(jnp.int32)
    topk_frame = jnp.where(valid, gal_frame[idx], -1).astype(jnp.int32)
    if topk_rerank:
        passing = valid & ((1.0 - sv) < match_thresh)
        matched = passing.any(axis=1)
        # per-camera summed passing score; one_hot(-1) is all-zero, so
        # invalid bands contribute nothing
        oh = jax.nn.one_hot(topk_cam, n_cams, dtype=jnp.float32)
        votes = jnp.einsum("qk,qkc->qc", jnp.where(passing, sv, 0.0), oh)
        rerank_cam = jnp.argmax(votes, axis=1).astype(jnp.int32)
        # the winning camera's best (lowest) passing band supplies the
        # matched embedding
        j = jnp.argmax(passing & (topk_cam == rerank_cam[:, None]), axis=1)
        best_idx = jnp.take_along_axis(si, j[:, None], axis=1)[:, 0]
        match_cam = jnp.where(matched, rerank_cam, 0).astype(jnp.int32)
    else:
        best_val, best_idx = sv[:, 0], si[:, 0]
        matched = (1.0 - best_val) < match_thresh
        match_cam = jnp.where(matched, gal_cam[jnp.maximum(best_idx, 0)],
                              0).astype(jnp.int32)
    idx0 = jnp.maximum(best_idx, 0)
    return matched, match_cam, gallery[idx0], sv, si, topk_cam, topk_frame


@partial(jax.jit, static_argnames=("match_thresh", "k", "topk_rerank"))
def rank_round(q_feat, q_frame, mask, gallery, gal_cam, gal_frame,
               match_thresh: float, k: int = 1, topk_rerank: bool = False):
    """One device pass over the round's deduplicated embedding batch.

    ``reid_topk_masked`` scores each query against exactly its admitted
    galleries; the argmax match path is unchanged by k > 1, the extra bands
    only surface candidates (unless ``topk_rerank`` turns on the §5.2
    confidence vote).  Returns (matched (Q,), match_cam (Q,),
    match_emb (Q, D), topk_val (Q, k), topk_idx (Q, k), topk_cam (Q, k),
    topk_frame (Q, k)).
    """
    sv, si = kernel_ops.reid_topk_masked(q_feat, q_frame, mask, gallery,
                                         gal_cam, gal_frame, k)
    return _rank_outcome(sv, si, gallery, gal_cam, gal_frame, match_thresh,
                         mask.shape[1], topk_rerank)


@partial(jax.jit, static_argnames=("match_thresh", "k", "topk_rerank"))
def rank_round_seg(q_feat, q_seg, mask, gallery, gal_cam, gal_frame, gal_seg,
                   match_thresh: float, k: int = 1,
                   topk_rerank: bool = False):
    """Consolidated variant of ``rank_round``: frame tags replaced by the
    ``RoundPlan``'s compact per-round segment ids (``q_seg`` (Q,) /
    ``gal_seg`` (G,)).  The relabeling is injective over the round's
    distinct content frames, so the masked score matrix — and every
    flat-argmin tie-break behind the (Q, k) bands — is bit-identical to the
    per-frame path; ``gal_frame`` still rides along so the trace records'
    top-k bands surface REAL frame ids, not segment ids.
    """
    sv, si = kernel_ops.reid_topk_segments(q_feat, q_seg, mask, gallery,
                                           gal_cam, gal_seg, k)
    return _rank_outcome(sv, si, gallery, gal_cam, gal_frame, match_thresh,
                         mask.shape[1], topk_rerank)


@partial(jax.jit, static_argnames=("match_thresh", "k", "n_cams",
                                   "topk_rerank"))
def rank_round_tiles(q_feat, q_seg, mask_ct, gallery, gal_ct, gal_cam,
                     gal_frame, gal_seg, match_thresh: float, k: int = 1,
                     n_cams: int = 0, topk_rerank: bool = False):
    """Tile-granular variant of ``rank_round_seg``: camera admission refined
    to the fused (camera, tile) mask ``mask_ct`` (Q, C*T*T) and per-row
    fused cell tags ``gal_ct`` (G,), ranked through ``reid_topk_tiles``.
    With every tile admitted the kernel's masked score matrix is
    bit-identical to ``reid_topk_segments`` — the camera-granular path is
    the differential oracle.  ``gal_cam``/``gal_frame`` ride along for the
    match outcome and trace bands exactly as in the segment path.
    """
    sv, si = kernel_ops.reid_topk_tiles(q_feat, q_seg, mask_ct, gallery,
                                        gal_ct, gal_seg, k)
    return _rank_outcome(sv, si, gallery, gal_cam, gal_frame, match_thresh,
                         n_cams, topk_rerank)


def rank_advance_round(policy: SearchPolicy, windows, state: PhaseState,
                       q_feat, mask, gallery, gal_cam, gal_frame, k: int = 1,
                       topk_rerank: bool = False):
    """The ONE serving step body both the single-process engine and the
    sharded fleet dispatch: rank the round's deduplicated gallery, then run
    the shared phase machine.  Pure over (Q,)-batched inputs, so the fleet
    can shard_map it over the query axis with the gallery replicated.

    The query cursor frames come from ``state.f_curr``; padding rows (done,
    all-False mask) therefore match nothing and surface (NEG_INF, -1) in
    the top-k bands — the same convention the kernels use for their own
    padded slots.
    """
    (matched, match_cam, match_emb, topk_val, topk_idx, topk_cam,
     topk_frame) = rank_round(q_feat, state.f_curr, mask, gallery, gal_cam,
                              gal_frame, policy.match_thresh, k, topk_rerank)
    nxt = advance(policy, windows, state, matched, match_cam, _NO_HORIZON)
    return (nxt, matched, match_cam, match_emb, topk_val, topk_idx,
            topk_cam, topk_frame)


def advance_round(policy: SearchPolicy, windows, state: PhaseState):
    """The no-gallery variant of the step body (nothing admitted anywhere
    this round): the phase machine alone, matched=False for every query."""
    Q = state.f_q.shape[0]
    return advance(policy, windows, state, jnp.zeros(Q, bool),
                   jnp.zeros(Q, jnp.int32), _NO_HORIZON)


def rank_advance_round_seg(policy: SearchPolicy, windows, state: PhaseState,
                           q_feat, q_seg, mask, gallery, gal_cam, gal_frame,
                           gal_seg, k: int = 1, topk_rerank: bool = False):
    """Consolidated step body: the whole round ranks in ONE segment-ID
    kernel call (``rank_round_seg``), then the same shared phase machine
    advances.  Pure over (Q,)-batched inputs like ``rank_advance_round`` —
    the fleet shard_maps it over the query axis with the gallery (and its
    cam/frame/segment tags) replicated."""
    (matched, match_cam, match_emb, topk_val, topk_idx, topk_cam,
     topk_frame) = rank_round_seg(q_feat, q_seg, mask, gallery, gal_cam,
                                  gal_frame, gal_seg, policy.match_thresh, k,
                                  topk_rerank)
    nxt = advance(policy, windows, state, matched, match_cam, _NO_HORIZON)
    return (nxt, matched, match_cam, match_emb, topk_val, topk_idx,
            topk_cam, topk_frame)


def rank_advance_round_tiles(policy: SearchPolicy, windows,
                             state: PhaseState, q_feat, q_seg, mask_ct,
                             gallery, gal_ct, gal_cam, gal_frame, gal_seg,
                             k: int = 1, n_cams: int = 0,
                             topk_rerank: bool = False):
    """Tile-granular step body: the whole round ranks in ONE tile-masked
    segment-ID kernel call (``rank_round_tiles``), then the same shared
    phase machine advances.  ``mask_ct`` (Q, C*T*T) is the fused
    (camera, tile) admission from ``policy.admit_tiles``; with every tile
    admitted this body is bit-identical to ``rank_advance_round_seg`` (the
    tile differential's oracle).  Pure over (Q,)-batched inputs — the fleet
    shard_maps it over the query axis with the gallery (and its
    cam/frame/segment/cell tags) replicated."""
    (matched, match_cam, match_emb, topk_val, topk_idx, topk_cam,
     topk_frame) = rank_round_tiles(q_feat, q_seg, mask_ct, gallery, gal_ct,
                                    gal_cam, gal_frame, gal_seg,
                                    policy.match_thresh, k, n_cams,
                                    topk_rerank)
    nxt = advance(policy, windows, state, matched, match_cam, _NO_HORIZON)
    return (nxt, matched, match_cam, match_emb, topk_val, topk_idx,
            topk_cam, topk_frame)


@partial(jax.jit, static_argnames=("policy", "k", "topk_rerank"))
def _rank_advance_jit(policy: SearchPolicy, windows, state: PhaseState,
                      q_feat, mask, gallery, gal_cam, gal_frame, k=1,
                      topk_rerank=False):
    return rank_advance_round(policy, windows, state, q_feat, mask,
                              gallery, gal_cam, gal_frame, k, topk_rerank)


@partial(jax.jit, static_argnames=("policy", "k", "topk_rerank"))
def _rank_advance_seg_jit(policy: SearchPolicy, windows, state: PhaseState,
                          q_feat, q_seg, mask, gallery, gal_cam, gal_frame,
                          gal_seg, k=1, topk_rerank=False):
    return rank_advance_round_seg(policy, windows, state, q_feat, q_seg,
                                  mask, gallery, gal_cam, gal_frame,
                                  gal_seg, k, topk_rerank)


@partial(jax.jit, static_argnames=("policy", "k", "n_cams", "topk_rerank"))
def _rank_advance_tiles_jit(policy: SearchPolicy, windows, state: PhaseState,
                            q_feat, q_seg, mask_ct, gallery, gal_ct, gal_cam,
                            gal_frame, gal_seg, k=1, n_cams=0,
                            topk_rerank=False):
    return rank_advance_round_tiles(policy, windows, state, q_feat, q_seg,
                                    mask_ct, gallery, gal_ct, gal_cam,
                                    gal_frame, gal_seg, k, n_cams,
                                    topk_rerank)


@partial(jax.jit, static_argnames=("policy",))
def _advance_round_jit(policy: SearchPolicy, windows, state: PhaseState):
    return advance_round(policy, windows, state)


_pow2 = pow2   # shared with runtime.gallery: one padding rule everywhere


@dataclasses.dataclass
class RoundPlan:
    """One round's fleet-global work queue, keyed by unique admitted
    (camera, frame).

    Built ONCE per round by ``_plan_round`` on the controller — the fleet's
    shards all consume the same plan, so no shard re-embeds or re-fetches a
    frame another shard's query already put in flight.  ``work`` is the
    camera-major sorted unique (cam, frame) demand (the order that keeps
    the kernels' flat-argmin tie-breaks bit-identical to the tracker);
    ``want_count`` records how many (query, camera) admission steps each
    key serves (the per-step miss convention — ``replay_miss_steps`` —
    reads it on eviction); ``seg_of_frame``/``q_seg`` carry the round's
    injective content-frame -> compact-segment relabeling for the
    consolidated ``reid_topk_segments`` ranking pass.
    """

    qs: list
    ps: PhaseState
    slots: np.ndarray
    mask: np.ndarray                        # (N, C) admission, host copy
    admitted: int                           # per-(query, camera) steps
    cams_by_q: list
    work: list                              # sorted unique (cam, frame)
    want_count: dict                        # key -> wanting (q, cam) pairs
    seg_of_frame: dict                      # content frame -> segment id
    q_seg: np.ndarray                       # (N,) int32, -1 on padding rows
    # tile mode only: the fused (camera, tile) admission (N, C*T*T) the
    # tile-masked ranking pass consumes; None under camera-granular serving
    mask_ct: np.ndarray | None = None

    def gallery_segments(self, batch_keys: list, key_emb: dict,
                         rows: int) -> np.ndarray:
        """Per-row segment tags for the assembled round gallery: each key's
        embedding block (in ``batch_keys`` order, exactly how
        ``assemble_round_gallery`` laid the rows out) gets its frame's
        segment id; padding rows carry -1 like the cam/frame tags."""
        gal_seg = np.full(rows, -1, np.int32)
        pos = 0
        for key in batch_keys:
            cnt = len(key_emb[key])
            gal_seg[pos:pos + cnt] = self.seg_of_frame[key[1]]
            pos += cnt
        return gal_seg


class ServingEngine:
    def __init__(self, model: SpatioTemporalModel, embed_fn: Callable,
                 cfg: EngineConfig, geo_adj=None):
        if cfg.topk < 1:
            raise ValueError(f"topk={cfg.topk} must be >= 1 (band 0 is the "
                             f"argmax match path)")
        self.tile_grid = int(cfg.tile_grid)
        if self.tile_grid > 0:
            model = self._resolve_tiles(model)
        self.model = model
        self.embed_fn = embed_fn
        self.cfg = cfg
        self.policy = cfg.policy
        self.C = model.n_cams
        self.model_epoch = int(model.epoch)  # host mirror for trace records
        self.model_swaps: list[tuple[int, int]] = []  # (tick, new epoch)
        # the geo baseline's proximity mask; all-ones when not provided
        # (same default as the tracker)
        self._geo_adj = jnp.asarray(
            geo_adj if geo_adj is not None else np.ones((self.C, self.C), bool))
        self.gallery = self._make_gallery()
        self.store = FrameStore(self.C, cfg.retention, gallery=self.gallery)
        # the double buffer over the gallery fetch plane (issue round N+1's
        # fetches while round N consumes) — harmless but pointless without a
        # transport, since the local path delivers immediately
        self._prefetch = PrefetchPipeline(self.store) if cfg.prefetch else None
        self.queries: dict[int, QueryState] = {}
        self.t = 0
        self.frames_processed = 0    # (cam, frame) batches actually embedded
        self.cache_hits = 0          # embed calls avoided by the cache
        self.replay_embeds = 0       # replay re-reads the cache missed
        self.admitted_steps = 0      # per-query camera-steps (tracker scale)
        self.unique_frames = 0       # deduplicated (cam, frame) pairs
        # tile mode only: per-(query, camera, tile) admission steps, and the
        # per-key unions of admitted tiles (the sub-frame pixel-load proxy —
        # camera-granular serving loads T*T tiles per admitted step / key)
        self.admitted_tiles = 0
        self.unique_tiles = 0
        self.content_steps = 0       # per-query content rounds charged
        self.replay_steps = 0        # content rounds behind the frontier
        self.skipped_steps = 0       # short-circuited sampled-out rounds
        self.replay_misses = 0       # replay reads past the retention window
        # the same misses in admitted_steps' per-(query, camera) convention:
        # an evicted key wanted by k queries is k rescue failures, not 1
        self.replay_miss_steps = 0
        self.ticks = 0
        # (C, C) replay-rescue attribution (phase >= 2 matches, keyed by the
        # anchor camera at match time) — the tracker's rescue_pairs, live:
        # the §6 drift-detection signal profiler.drift_score consumes
        self.rescue_pairs = np.zeros((self.C, self.C), np.int64)
        # (qid, cam, frame) confirmed-sighting log: the query's submit anchor
        # plus every match — the engine's own trajectory record, which
        # runtime.recal.match_log_source can re-profile from (§6).  A deque
        # pruned each tick past the largest window anyone can replay into
        # (frame retention, or the recal window when a controller is
        # attached), so a long-running engine's memory stays bounded.
        self.sightings: collections.deque[tuple[int, int, int]] = \
            collections.deque()
        self.recal = None            # attached RecalibrationController
        self._in_round = False       # swap_model atomicity guard
        self._slots = np.zeros(0, np.int64)  # qs-index -> batch-row mapping
        # high-water marks freezing steady-state jit signatures: the padded
        # batch and round gallery never shrink below a size already compiled.
        # Growth-only padding is trace-neutral — padding rows are done/masked
        # and rank to (NEG_INF, -1) — so a shrinking cohort or gallery reuses
        # the compiled shape instead of minting a smaller signature every
        # time it dips (what RecompileGuard would trip on).
        self._batch_hwm = 1
        self._gal_rows_hwm = 1
        self._windows = phase_windows(model, cfg.policy)
        # host copies of the exhaustion windows for the skip fast path
        self._w1 = np.asarray(self._windows.w_end1)
        self._w2 = np.asarray(self._windows.w_end2)

    # -- the correlation model (the control plane's only persistent state) --
    def _resolve_tiles(self, model: SpatioTemporalModel) -> SpatioTemporalModel:
        """Reconcile a model with the engine's ``cfg.tile_grid``: a model
        profiled WITHOUT tile data gets the all-tiles-admitted tensor
        (trace-identical to camera-granular serving — the tile
        differential's oracle); a model profiled at a different grid is a
        config error, not something to resample silently."""
        if model.tile_grid not in (0, self.tile_grid):
            raise ValueError(
                f"tile_grid mismatch: engine serves T={self.tile_grid} but "
                f"the model was profiled at T={model.tile_grid} — re-profile "
                f"with profile(..., tile_grid={self.tile_grid})")
        if model.tile_admit is None or model.tile_grid == 0:
            C, TT = model.n_cams, self.tile_grid * self.tile_grid
            model = dataclasses.replace(
                model, tile_admit=jnp.ones((C, C, TT), bool),
                tile_grid=self.tile_grid, tile_learned=False)
        return model

    def swap_model(self, model: SpatioTemporalModel) -> int:
        """Hot-swap the spatio-temporal model M without dropping in-flight
        queries (§6 recalibration): the next round admits/ranks under the new
        model while every query keeps its anchor, cursor and phase.  The
        phase-exhaustion windows (device + host skip-path copies) are rebuilt
        so both step paths switch together, and the model epoch bumps — trace
        records carry it, which is how the differential harness pins the
        fleet's swap to the same round as the single engine's.

        M's arrays must keep their shapes ((C, C[, NB])), so the jitted step
        bodies never recompile on a swap; swaps land BETWEEN rounds (calling
        mid-round raises — the atomicity contract the fleet relies on, since
        one round's admit and rank must see the same M on every shard).
        Returns the new epoch."""
        if self._in_round:
            raise RuntimeError(
                "swap_model called mid-round: the model must stay constant "
                "within a round (admit and rank see one M) — swap between "
                "ticks, e.g. from RecalibrationController.on_tick")
        if model.n_cams != self.C or model.n_bins != self.model.n_bins \
                or model.bin_width != self.model.bin_width:
            raise ValueError(
                f"swap_model shape mismatch: engine serves C={self.C}, "
                f"NB={self.model.n_bins}, bin_width={self.model.bin_width}; "
                f"got C={model.n_cams}, NB={model.n_bins}, "
                f"bin_width={model.bin_width} (re-profile with the same "
                f"n_bins/bin_width — bin_width is jit-static, so a mismatch "
                f"would recompile every step body)")
        if self.tile_grid > 0:
            # epoch-versioned tile carry: a recalibration that re-profiled
            # WITHOUT tile data keeps serving the incumbent learned masks
            # (they ride the swap forward); a re-profile WITH tile data at
            # the serving grid hot-swaps them like every other model array
            if model.tile_admit is None or model.tile_grid == 0:
                model = dataclasses.replace(
                    model, tile_admit=self.model.tile_admit,
                    tile_grid=self.tile_grid,
                    tile_learned=self.model.tile_learned)
            else:
                model = self._resolve_tiles(model)
        self.model_epoch += 1
        if int(model.epoch) != self.model_epoch:
            model = dataclasses.replace(model, epoch=self.model_epoch)
        self.model = model
        self._windows = phase_windows(model, self.cfg.policy)
        self._w1 = np.asarray(self._windows.w_end1)
        self._w2 = np.asarray(self._windows.w_end2)
        self.model_swaps.append((self.t, self.model_epoch))
        return self.model_epoch

    # -- the gallery plane -------------------------------------------------
    def _make_gallery(self) -> GalleryStore:
        """Which GalleryStore backs the embedding plane.  The fleet
        overrides this to inject the shared ``ShardedGalleryStore``."""
        if self.cfg.transport is not None:
            raise ValueError(
                "transport= requires the sharded fleet gallery "
                "(serve(..., shards=k)); the single engine's local store "
                "has no remote owners to fetch from")
        if self.cfg.gallery in ("auto", "local"):
            return LocalGalleryStore(self.C, self.cfg.retention)
        if self.cfg.gallery == "sharded":
            raise ValueError(
                "gallery='sharded' requires the sharded fleet "
                "(serve(..., shards=k)); the single engine is local-only")
        raise ValueError(f"unknown gallery mode {self.cfg.gallery!r} "
                         f"(expected 'auto', 'local' or 'sharded')")

    def gallery_report(self) -> dict:
        """The embedding plane's own accounting: backend kind plus
        hit/miss/eviction/put counters and resident memory.  Rescue-failure
        cost rides along in BOTH conventions: ``replay_misses`` per unique
        evicted key, ``replay_miss_steps`` per wanting (query, camera)
        step (comparable with ``admitted_steps``)."""
        return dict(kind=self.gallery.kind,
                    replay_misses=self.replay_misses,
                    replay_miss_steps=self.replay_miss_steps,
                    **self.gallery.counters())

    # -- query lifecycle --------------------------------------------------
    def submit_query(self, qid: int, feat: np.ndarray, cam: int, frame: int):
        self.queries[qid] = QueryState(
            qid, l2_normalize(feat), cam, frame, f_curr=frame + 1,
            submit_t=self.t)
        self.sightings.append((qid, cam, frame))

    def _on_query_done(self, q: QueryState) -> None:
        """Fired exactly once per query, on its not-done -> done transition
        (both the device round and the host skip fast path).  The fleet
        keeps its O(1) per-worker live-load counters here."""

    # -- batched state marshalling ---------------------------------------
    def _layout(self, qs: list[QueryState]) -> tuple[int, np.ndarray]:
        """(batch size N, slots): which padded-batch row each query in ``qs``
        occupies.  The single-process engine packs queries densely and pads
        to the next power of two (O(log Q) jit shapes); the sharded fleet
        overrides this to group rows by worker placement, each shard block
        padded to a shard-uniform power of two.  Both hold the batch at its
        high-water mark so a shrinking cohort keeps the compiled shape."""
        n = len(qs)
        self._batch_hwm = max(self._batch_hwm, _pow2(n))
        return self._batch_hwm, np.arange(n)

    def prime_batch(self, n_queries: int) -> None:
        """Pre-size the padded batch for an expected peak of ``n_queries``
        live queries.  Round cohorts grow lazily (a 3-query cohort may
        first form hundreds of ticks in), and each pow2 growth mints a new
        jit signature — pre-sizing moves all of them into warmup, so a
        RecompileGuard-ed steady state compiles nothing.  Trace-neutral by
        the hwm layout rule: padding rows are done/masked and rank to
        (NEG_INF, -1)."""
        self._batch_hwm = max(self._batch_hwm, _pow2(max(int(n_queries), 1)))

    def prime_gallery(self, rows: int) -> None:
        """Pre-size the padded round gallery for an expected peak of
        ``rows`` embedding rows.  The gallery side of the rank signature
        has the same lazy-growth problem as the batch side: a phase-2
        rescue hundreds of ticks in can admit the largest round gallery
        yet, and each pow2 growth of ``_gal_rows_hwm`` mints a new rank
        signature.  Trace-neutral: padded rows carry cam/frame -1 and rank
        to (NEG_INF, -1) inside the kernels."""
        self._gal_rows_hwm = max(self._gal_rows_hwm,
                                 _pow2(max(int(rows), 1)))

    @property
    def padded_gallery_rows(self) -> int:
        """Current round-gallery row high-water mark (pow2-padded) — feed
        it back through ``prime_gallery`` on a fresh engine to replay the
        same workload without mid-run shape growth."""
        return self._gal_rows_hwm

    def _gather(self, qs: list[QueryState]) -> PhaseState:
        """Engine QueryStates -> one batched PhaseState.  The live frontier
        is the engine wall clock: frames through ``self.t`` are ingested.

        Row assignment comes from ``_layout`` (stored in ``self._slots`` for
        the rest of the round); every non-query row is padding — ``done``,
        so it admits nothing, never advances, and ranks to (NEG_INF, -1)
        exactly like the kernels' own padded slots.
        """
        N, slots = self._layout(qs)
        self._slots = slots

        def col(vals, fill, dtype):
            a = np.full(N, fill, dtype)
            a[slots] = vals
            return jnp.asarray(a)

        return PhaseState(
            f_q=col([q.f_q for q in qs], 0, np.int32),
            c_q=col([q.c_q for q in qs], 0, np.int32),
            f_curr=col([q.f_curr for q in qs], 0, np.int32),
            phase=col([q.phase for q in qs], 1, np.int32),
            live_f=col([float(self.t)] * len(qs), 0.0, np.float32),
            done=col([False] * len(qs), True, np.bool_),
        )

    def _scatter(self, qs: list[QueryState], ps: PhaseState,
                 matched: np.ndarray, match_cam: np.ndarray,
                 match_emb: np.ndarray | None):
        """Write the advanced PhaseState back into the QueryState objects."""
        a = self.policy.feat_alpha
        sl = self._slots
        f_q = np.asarray(ps.f_q)
        c_q = np.asarray(ps.c_q)
        f_curr = np.asarray(ps.f_curr)
        phase = np.asarray(ps.phase)
        done = np.asarray(ps.done)
        for i, q in enumerate(qs):
            j = sl[i]
            if matched[j]:
                emb = match_emb[j]
                q.feat = l2_normalize((1 - a) * q.feat + a * emb)
                if q.first_match_t < 0:   # detection delay (Fig. 15 metric)
                    q.first_match_t = self.t
                if q.phase >= 2:
                    q.rescued += 1
                    self.rescue_pairs[q.c_q, int(match_cam[j])] += 1
                q.matches.append((int(match_cam[j]), int(q.f_curr)))
                self.sightings.append((q.qid, int(match_cam[j]),
                                       int(q.f_curr)))
            q.f_q, q.c_q = int(f_q[j]), int(c_q[j])
            q.f_curr, q.phase = int(f_curr[j]), int(phase[j])
            q.done = bool(done[j])
            if q.done:          # qs never contains done queries: a transition
                self._on_query_done(q)

    # -- device dispatch ---------------------------------------------------
    # The fleet overrides these three to run the SAME step bodies under
    # shard_map over the query axis (model/windows/gallery replicated).
    def _dispatch_admit(self, ps: PhaseState):
        return _admit_jit(self.model, self.policy, ps, self._geo_adj)

    def _dispatch_admit_tiles(self, ps: PhaseState, tile_q):
        return _admit_tiles_jit(self.model, self.policy, ps, self._geo_adj,
                                tile_q)

    def _dispatch_rank_advance(self, ps: PhaseState, q_feat, mask, gallery,
                               gal_cam, gal_frame):
        return _rank_advance_jit(self.policy, self._windows, ps, q_feat,
                                 mask, gallery, gal_cam, gal_frame,
                                 k=self.cfg.topk,
                                 topk_rerank=self.cfg.topk_rerank)

    def _dispatch_rank_advance_seg(self, ps: PhaseState, q_feat, q_seg,
                                   mask, gallery, gal_cam, gal_frame,
                                   gal_seg):
        return _rank_advance_seg_jit(self.policy, self._windows, ps, q_feat,
                                     q_seg, mask, gallery, gal_cam,
                                     gal_frame, gal_seg, k=self.cfg.topk,
                                     topk_rerank=self.cfg.topk_rerank)

    def _dispatch_rank_advance_tiles(self, ps: PhaseState, q_feat, q_seg,
                                     mask_ct, gallery, gal_ct, gal_cam,
                                     gal_frame, gal_seg):
        return _rank_advance_tiles_jit(self.policy, self._windows, ps,
                                       q_feat, q_seg, mask_ct, gallery,
                                       gal_ct, gal_cam, gal_frame, gal_seg,
                                       k=self.cfg.topk, n_cams=self.C,
                                       topk_rerank=self.cfg.topk_rerank)

    def _dispatch_advance(self, ps: PhaseState):
        return _advance_round_jit(self.policy, self._windows, ps)

    def _plan_round(self, qs: list[QueryState]) -> RoundPlan:
        """Gather + admit, then build the round's fleet-global work queue:
        the deduplicated (cam, frame) demand with per-key want counts, and
        the injective content-frame -> segment relabeling the consolidated
        ranking pass tags queries and gallery rows with."""
        ps = self._gather(qs)
        sl = self._slots
        mask_ct = None
        if self.tile_grid > 0:
            # one fused admit pass: the (N, C) camera mask (identical to
            # _dispatch_admit by construction — mask_ct reduces to it over
            # the tile axis) plus the (N, C*T*T) tile-refined admission.
            # tile_q rides along padded like every batch column (-1 =
            # unknown, which admits every self tile)
            tq = np.full(ps.f_q.shape[0], -1, np.int32)
            tq[sl] = [q.tile_q for q in qs]
            m, m_ct = self._dispatch_admit_tiles(ps, jnp.asarray(tq))
            mask, mask_ct = np.asarray(m), np.asarray(m_ct)
        else:
            mask = np.asarray(self._dispatch_admit(ps))              # (N, C)
        cams_by_q = [np.flatnonzero(mask[sl[i]]) for i in range(len(qs))]
        want_count: dict[tuple[int, int], int] = {}
        for i, q in enumerate(qs):
            for cam in cams_by_q[i]:
                key = (int(cam), q.f_curr)
                want_count[key] = want_count.get(key, 0) + 1
        seg_of_frame = {f: s for s, f in
                        enumerate(sorted({q.f_curr for q in qs}))}
        q_seg = np.full(mask.shape[0], -1, np.int32)
        for i, q in enumerate(qs):
            q_seg[sl[i]] = seg_of_frame[q.f_curr]
        return RoundPlan(qs=qs, ps=ps, slots=sl, mask=mask,
                         admitted=int(mask[sl].sum()), cams_by_q=cams_by_q,
                         work=sorted(want_count), want_count=want_count,
                         seg_of_frame=seg_of_frame, q_seg=q_seg,
                         mask_ct=mask_ct)

    def _account_round(self, plan: RoundPlan) -> None:
        """Per-round accounting hook over the shared ``RoundPlan`` —
        ``plan.cams_by_q[i]`` is the camera set query i admitted,
        ``plan.work`` the round's globally-deduplicated (cam, frame) demand
        (the fleet adds per-shard cost here)."""

    # -- per-tick ----------------------------------------------------------
    def ingest(self, frames_by_cam: dict[int, Any],
               tiles_by_cam: dict[int, Any] | None = None):
        """New live frames at the current step (frame = detector crops).

        Tile mode (``cfg.tile_grid > 0``) additionally requires per-camera
        flat tile ids, one per detection crop (``tiles_by_cam[cam][i]`` =
        ``ty * T + tx`` for crop i — ``core.simulate.tile_index`` maps
        normalized positions to them).  Labels are MANDATORY: a gallery row
        without a tile cell would either silently match nothing or need a
        wildcard that breaks the all-admitted <-> camera-path equivalence,
        so a missing/mismatched label set raises instead."""
        for cam, frame in frames_by_cam.items():
            tile = None
            if self.tile_grid > 0:
                tile = None if tiles_by_cam is None else tiles_by_cam.get(cam)
                if tile is None:
                    raise ValueError(
                        f"tile_grid={self.tile_grid} serving requires per-"
                        f"detection tile labels: ingest(frames_by_cam, "
                        f"tiles_by_cam) got none for camera {cam}")
                if len(tile) != len(frame):
                    raise ValueError(
                        f"camera {cam}: {len(tile)} tile labels for "
                        f"{len(frame)} detections at t={self.t}")
                tile = np.asarray(tile, np.int32)
            self.store.append(cam, self.t, frame, tile=tile)

    def tick(self, record_trace: list | None = None) -> dict:
        """One admission+inference round over all live queries at once.

        A caught-up query consumes one content step; a replaying query
        consumes up to ``policy.replay_rate`` content steps (extra rounds),
        which is how fast-forward mode catches up.  Returns stats; pass a
        list as ``record_trace`` to collect (qid, f_curr, phase, mask) per
        processed round (the parity-test hook).
        """
        stats = {"t": self.t, "admitted_steps": 0, "unique_frames": 0,
                 "batched": 0, "embedded": 0, "cache_hits": 0,
                 "replay_embeds": 0, "matches": 0, "replay_misses": 0,
                 "replay_miss_steps": 0, "content_steps": 0,
                 "replay_steps": 0, "skipped_rounds": 0,
                 "admitted_tiles": 0, "unique_tiles": 0}
        # Replay pacing: a lagging query earns policy.replay_rate content
        # rounds per wall tick, with the fractional remainder carried across
        # ticks so e.g. replay_speed=1.5 really averages 1.5x, matching the
        # tracker's continuous live_f model.  Caught-up queries get 1 round.
        # drop prefetch handles whose blocks got evicted since they were
        # issued (ingest ran between ticks) — exact waste accounting and a
        # buffer bounded by the cache size
        if self._prefetch is not None:
            self._prefetch.sweep()
        budget = {}
        for q in self.queries.values():
            if q.done:
                continue
            if q.f_curr >= self.t:
                q.replay_credit = 0.0
                budget[q.qid] = 1
            else:
                q.replay_credit += self.policy.replay_rate
                rounds = int(q.replay_credit)
                q.replay_credit -= rounds
                budget[q.qid] = rounds
        while True:
            qs = [q for q in self.queries.values()
                  if not q.done and budget.get(q.qid, 0) > 0
                  and q.f_curr <= self.t]
            if not qs:
                break
            for q in qs:
                if q.f_curr < self.t:
                    budget[q.qid] -= 1
                else:
                    # live queries only get 1 content step per wall tick; a
                    # replayer that caught up mid-tick banks its unspent
                    # budget back into replay_credit (the credit was already
                    # decremented at tick start — forfeiting it here would
                    # undershoot policy.replay_rate long-run)
                    q.replay_credit += budget[q.qid] - 1
                    budget[q.qid] = 0
            self._round(qs, stats, record_trace)
        self.t += 1
        self.ticks += 1
        # drift-aware recalibration (§6): the attached controller polls the
        # live rescue matrix and may hot-swap M — strictly between rounds,
        # so the swap is atomic across the whole fleet's next round
        if self.recal is not None:
            self.recal.on_tick()
        # bound the sighting log: drop entries no recalibration window can
        # still reach (sightings arrive near-sorted by frame — submit
        # anchors and replay matches lag at most a window behind — so
        # stopping at the first young head is amortized O(1) per tick)
        keep = max(self.cfg.retention,
                   self.recal.policy.window if self.recal is not None else 0)
        cutoff = self.t - 2 * keep
        while self.sightings and self.sightings[0][2] < cutoff:
            self.sightings.popleft()
        return stats

    def _round(self, qs: list[QueryState], stats: dict,
               trace: list | None) -> None:
        self._in_round = True
        try:
            self._round_body(qs, stats, trace)
        finally:
            self._in_round = False

    def _round_body(self, qs: list[QueryState], stats: dict,
                    trace: list | None) -> None:
        stats["content_steps"] += len(qs)
        self.content_steps += len(qs)
        replaying = sum(q.f_curr < self.t for q in qs)
        stats["replay_steps"] += replaying
        self.replay_steps += replaying

        # §5.3 skip mode: a sampled-out replay cursor admits nothing by
        # construction — advance it on the host instead of paying a full
        # gather/admit/rank dispatch (the content step is already charged).
        # Trace records are buffered per qid and emitted in the original
        # round order so the fast path stays trace-identical to the slow one.
        all_qs = qs
        records: dict[int, dict] = {}
        if self.cfg.short_circuit_skips and self.policy.replay_skip > 1:
            gated = [q for q in qs
                     if replay_sampled_out(self.policy, q.f_q, q.f_curr,
                                           q.f_curr < self.t)]
            if gated:
                self._skip_round(gated, stats,
                                 records if trace is not None else None)
                gated_ids = {q.qid for q in gated}
                qs = [q for q in qs if q.qid not in gated_ids]
                if not qs:
                    if trace is not None:
                        trace.extend(records[q.qid] for q in all_qs)
                    if self._prefetch is not None:
                        self._issue_prefetch(all_qs)
                    return

        # the round's fleet-global work queue: one plan, every shard's
        # queries — each admitted (cam, frame) pair embeds/fetches once no
        # matter how many queries (on whichever shard) want it
        plan = self._plan_round(qs)
        ps, sl, mask = plan.ps, plan.slots, plan.mask
        stats["admitted_steps"] += plan.admitted
        self.admitted_steps += plan.admitted
        self._account_round(plan)
        stats["unique_frames"] += len(plan.work)
        self.unique_frames += len(plan.work)
        if self.tile_grid > 0:
            # both cost conventions, tile-refined: admitted_tiles is
            # per-(query, camera, tile) steps (camera-granular serving
            # would charge T*T per admitted step); unique_tiles is the
            # per-key UNION of admitted tiles (the deduplicated sub-frame
            # pixel-load proxy — camera-granular loads T*T per unique key)
            TT = self.tile_grid * self.tile_grid
            adm_tiles = int(plan.mask_ct[sl].sum())
            stats["admitted_tiles"] += adm_tiles
            self.admitted_tiles += adm_tiles
            tiles_by_key: dict[tuple[int, int], np.ndarray] = {}
            for i, q in enumerate(qs):
                row = plan.mask_ct[sl[i]]
                for cam in plan.cams_by_q[i]:
                    key = (int(cam), q.f_curr)
                    seg = row[key[0] * TT:(key[0] + 1) * TT]
                    if key in tiles_by_key:
                        tiles_by_key[key] |= seg
                    else:
                        tiles_by_key[key] = seg.copy()
            uniq_tiles = sum(int(v.sum()) for v in tiles_by_key.values())
            stats["unique_tiles"] += uniq_tiles
            self.unique_tiles += uniq_tiles

        # camera-major key order (plan.work is sorted): ascending gallery
        # index reproduces the tracker's flat-argmin tie-break within every
        # query's admitted set
        batch_keys: list[tuple[int, int]] = []
        frames: dict[tuple[int, int], Any] = {}
        key_emb: dict[tuple[int, int], np.ndarray] = {}
        for key in plan.work:
            if self.cfg.embed_cache:
                # prefetched blocks first (round N-1 speculated this key);
                # any misspeculation falls back to the blocking fetch below
                emb = None
                if self._prefetch is not None:
                    emb = self._prefetch.consume(*key)
                if emb is None:
                    emb = self.store.get_emb(*key)
                if emb is not None:     # replay re-read: skip re-embedding
                    key_emb[key] = emb
                    batch_keys.append(key)
                    stats["cache_hits"] += 1
                    self.cache_hits += 1
                    continue
            try:
                frame = self.store.get(*key)
            except KeyError:            # evicted: cold-storage miss (§5.3)
                # both conventions: one per unique key, plus one per wanting
                # (query, camera) step — a key shared by k queries is k
                # failed rescues at admitted_steps scale
                self.replay_misses += 1
                stats["replay_misses"] += 1
                self.replay_miss_steps += plan.want_count[key]
                stats["replay_miss_steps"] += plan.want_count[key]
                continue
            if frame is not None and len(frame):
                batch_keys.append(key)
                frames[key] = frame
        stats["batched"] += len(batch_keys)

        to_embed = [k for k in batch_keys if k not in key_emb]
        for start in range(0, len(to_embed), self.cfg.max_batch):
            keys = to_embed[start:start + self.cfg.max_batch]
            counts = [len(frames[key]) for key in keys]
            crops = [c for key in keys for c in frames[key]]
            emb = l2_normalize(self.embed_fn(np.stack(crops)))  # (n, D)
            self.frames_processed += len(keys)
            stats["embedded"] += len(keys)
            # keys behind the wall clock are replay re-reads the cache missed
            replay_embeds = sum(key[1] < self.t for key in keys)
            stats["replay_embeds"] += replay_embeds
            self.replay_embeds += replay_embeds
            pos = 0
            for key, cnt in zip(keys, counts):
                key_emb[key] = emb[pos:pos + cnt]
                if self.cfg.embed_cache:
                    # the frame was just read out of the store, so it IS
                    # retained — a refused write here is a bookkeeping bug
                    # (raise, not assert: must survive python -O)
                    if not self.store.put_emb(*key, key_emb[key]):
                        raise RuntimeError(
                            f"engine tried to cache un-retained frame {key}")
                pos += cnt

        # one rank+advance pass over the whole round, through the step body
        # both engines share: every query scores exactly its admitted
        # galleries via the segment-masked reid kernel, then the phase
        # machine advances — matched plus the (N, k) top-k bands come back
        # per row with padding rows as (False, NEG_INF, -1)
        N = mask.shape[0]
        K = self.cfg.topk
        matched = np.zeros(N, bool)
        match_cam = np.zeros(N, np.int32)
        topk_val = np.full((N, K), NEG_INF, np.float32)
        topk_idx = np.full((N, K), -1, np.int32)
        topk_cam = np.full((N, K), -1, np.int32)
        topk_frame = np.full((N, K), -1, np.int32)
        match_emb = None
        if batch_keys:
            # camera-major key order was fixed above; assembly + pow2 pad
            # live in the gallery plane so both engines share one rule
            gal, gal_cam, gal_frame = assemble_round_gallery(
                batch_keys, key_emb, min_rows=self._gal_rows_hwm)
            self._gal_rows_hwm = max(self._gal_rows_hwm, gal.shape[0])
            q_feat = np.zeros((N, gal.shape[1]), np.float32)
            for i, q in enumerate(qs):
                q_feat[sl[i]] = q.feat
            if self.tile_grid > 0:
                # tile path: ONE tile-masked segment-ID kernel call ranks
                # the whole round regardless of cfg.consolidate (the
                # relabeling is injective, so consolidation cannot change
                # the outcome — pinned by the tile differential).  Every
                # gallery row carries its fused (camera, tile) cell from
                # the ingest-time labels.
                TT = self.tile_grid * self.tile_grid
                gal_ct = np.full(gal.shape[0], -1, np.int32)
                pos = 0
                for key in batch_keys:
                    cnt = len(key_emb[key])
                    tiles_k = self.store.get_tile(*key)
                    if tiles_k is None or len(tiles_k) != cnt:
                        # ingest enforces labels, so this is a bookkeeping
                        # bug (eviction raced a replay read), not user error
                        raise RuntimeError(
                            f"tile labels missing/mismatched for {key}: "
                            f"got {None if tiles_k is None else len(tiles_k)}"
                            f" for {cnt} gallery rows")
                    gal_ct[pos:pos + cnt] = key[0] * TT + \
                        np.asarray(tiles_k, np.int32)
                    pos += cnt
                gal_seg = plan.gallery_segments(batch_keys, key_emb,
                                                gal.shape[0])
                (ps_next, m, mc, me, tv, ti, tc,
                 tf) = self._dispatch_rank_advance_tiles(
                    ps, jnp.asarray(q_feat), jnp.asarray(plan.q_seg),
                    jnp.asarray(plan.mask_ct), jnp.asarray(gal),
                    jnp.asarray(gal_ct), jnp.asarray(gal_cam),
                    jnp.asarray(gal_frame), jnp.asarray(gal_seg))
            elif self.cfg.consolidate:
                # consolidated path: ONE segment-ID kernel call ranks the
                # whole round — frames relabeled to the plan's compact
                # segment ids, gal_frame riding along for the trace bands
                gal_seg = plan.gallery_segments(batch_keys, key_emb,
                                                gal.shape[0])
                (ps_next, m, mc, me, tv, ti, tc,
                 tf) = self._dispatch_rank_advance_seg(
                    ps, jnp.asarray(q_feat), jnp.asarray(plan.q_seg),
                    jnp.asarray(mask), jnp.asarray(gal),
                    jnp.asarray(gal_cam), jnp.asarray(gal_frame),
                    jnp.asarray(gal_seg))
            else:
                (ps_next, m, mc, me, tv, ti, tc,
                 tf) = self._dispatch_rank_advance(
                    ps, jnp.asarray(q_feat), jnp.asarray(mask),
                    jnp.asarray(gal), jnp.asarray(gal_cam),
                    jnp.asarray(gal_frame))
            matched = np.asarray(m)
            match_cam = np.asarray(mc)
            match_emb = np.asarray(me)
            topk_val = np.asarray(tv)
            topk_idx = np.asarray(ti)
            topk_cam = np.asarray(tc)
            topk_frame = np.asarray(tf)
            stats["matches"] += int(matched[sl].sum())
            if self.tile_grid > 0:
                # follow-window state: a confirmed match pins the query to
                # the matched gallery row's tile (gal_ct carries the fused
                # cell; % T*T recovers the tile) — the next round's learned
                # self-camera admission narrows around it
                TT = self.tile_grid * self.tile_grid
                for i, q in enumerate(qs):
                    j = sl[i]
                    if not matched[j]:
                        continue
                    mi = int(topk_idx[j, 0])
                    if self.cfg.topk_rerank:
                        # re-ranked matches re-anchor to the winning
                        # camera's best band, not band 0
                        for b in range(K):
                            if topk_cam[j, b] == match_cam[j]:
                                mi = int(topk_idx[j, b])
                                break
                    if mi >= 0 and gal_ct[mi] >= 0:
                        q.tile_q = int(gal_ct[mi]) % TT
        else:
            ps_next = self._dispatch_advance(ps)

        if trace is not None:
            for i, q in enumerate(qs):
                j = sl[i]
                records[q.qid] = dict(
                    qid=q.qid, f_curr=q.f_curr, phase=q.phase,
                    epoch=self.model_epoch,
                    mask=mask[j].copy(), matched=bool(matched[j]),
                    match_cam=int(match_cam[j]),
                    match_val=float(topk_val[j, 0]),
                    match_idx=int(topk_idx[j, 0]),
                    topk=tuple((float(topk_val[j, b]), int(topk_cam[j, b]),
                                int(topk_frame[j, b])) for b in range(K)))
            trace.extend(records[q.qid] for q in all_qs)

        self._scatter(qs, ps_next, matched, match_cam, match_emb)

        # double-buffer: with the round's outcomes scattered, the cohort's
        # NEXT cursors are known — speculate round N+1's admission and start
        # its cached fetches now, so they deliver while other work runs
        if self._prefetch is not None:
            self._issue_prefetch(all_qs)

    def _issue_prefetch(self, qs: list[QueryState]) -> None:
        """Speculatively issue async fetches for the cohort's next round.

        ``policy.advance`` already produced the next cursors/phases, so the
        next admission mask is re-evaluated on the REAL advanced state; the
        only guesses are the live frontier (``self.t`` — next tick moves it)
        and anything that mutates between rounds (a model swap, eviction, a
        resubmitted query).  Guesses only cost accuracy, never correctness:
        ``PrefetchPipeline.consume`` validates at use time and the round
        falls back to the blocking fetch — the trace cannot change.
        """
        # only replay cursors (f_curr behind the live frontier) can read a
        # cache-RESIDENT block — a live-frontier block was ingested this tick
        # and is not embedded yet, so issuing its key either declines or,
        # worse, strands a handle that counts as prefetch_wasted when a
        # concurrent replayer happened to embed the frame.  Filtering to
        # replay cursors (not just skipping when NOBODY replays) keeps the
        # waste metric honest in mixed cohorts and keeps the speculative
        # admit dispatch proportional to the replay rounds.
        live = [q for q in qs if not q.done and q.f_curr < self.t]
        if not live:
            return
        ps = self._gather(live)
        sl = self._slots
        mask = np.asarray(self._dispatch_admit(ps))
        keys: set[tuple[int, int]] = set()
        for i, q in enumerate(live):
            for cam in np.flatnonzero(mask[sl[i]]):
                keys.add((int(cam), q.f_curr))
        self._prefetch.issue(keys)

    def _skip_round(self, qs: list[QueryState], stats: dict,
                    records: dict | None) -> None:
        """Host mirror of one no-match ``policy.advance`` step for
        sampled-out replay rounds (their admission mask is all-False, so no
        inference can happen; only the cursor/phase machine moves).  Must
        stay transition-identical to ``advance`` with matched=False —
        pinned by the fast-path equivalence regression test.
        """
        stats["skipped_rounds"] += len(qs)
        self.skipped_steps += len(qs)
        if records is not None:
            empty_topk = ((float(NEG_INF), -1, -1),) * self.cfg.topk
            for q in qs:
                records[q.qid] = dict(qid=q.qid, f_curr=q.f_curr,
                                      phase=q.phase, epoch=self.model_epoch,
                                      mask=np.zeros(self.C, bool),
                                      matched=False, match_cam=0,
                                      match_val=float(NEG_INF), match_idx=-1,
                                      topk=empty_topk)
        p = self.policy
        for q in qs:
            f_next = q.f_curr + 1
            el_next = f_next - q.f_q
            if p.scheme in ("all", "geo") or not p.use_replay:
                done = el_next > p.exit_t or f_next >= _NO_HORIZON
                f_new, phase_new = f_next, q.phase
            else:
                nothing_relaxed = self._w2[q.c_q] <= p.self_window
                exh1 = q.phase == 1 and el_next > self._w1[q.c_q]
                exh2 = q.phase == 2 and el_next > self._w2[q.c_q]
                exh3 = q.phase >= 3 and el_next > p.exit_t
                if p.exhaustive_final:
                    esc = exh1 or exh2
                    done = exh3 or f_next >= _NO_HORIZON
                else:
                    esc = exh1 and not nothing_relaxed
                    done = ((exh1 and nothing_relaxed) or exh2 or exh3
                            or f_next >= _NO_HORIZON)
                phase_new = q.phase + 1 if esc else q.phase
                f_new = q.f_q + 1 if esc else f_next
            q.f_curr, q.phase, q.done = f_new, phase_new, bool(done)
            if q.done:
                self._on_query_done(q)
