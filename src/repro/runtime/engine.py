"""The serving engine: ReXCam admission control over the inference plane.

Per tick (one content step over all live camera streams):

  1. every active tracking query asks the spatio-temporal model which
     (camera, frame) pairs to admit (``repro.core.tracker`` semantics),
  2. admitted frames are deduplicated across queries (a frame is detected /
     embedded once no matter how many queries want it — the fleet-scale
     batching win),
  3. the batch runs through the backbone embed function and the
     ``reid_topk`` kernel against each query's representation,
  4. matches update tracker states; misses escalate to replay, which reads
     the ``FrameStore`` ring buffer.

The engine is deliberately backbone-agnostic: ``embed_fn(frames) ->
(n, D)`` may be a smoke-scale transformer from ``repro.models`` or the
simulator's feature oracle (tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core.correlation import SpatioTemporalModel
from repro.runtime.stream_store import FrameStore


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    s_thresh: float = 0.05
    t_thresh: float = 0.02
    match_thresh: float = 0.28
    feat_alpha: float = 0.25
    relax_factor: float = 10.0
    self_window: int = 6
    exit_t: int = 240
    max_batch: int = 256
    retention: int = 600


@dataclasses.dataclass
class QueryState:
    qid: int
    feat: np.ndarray
    c_q: int
    f_q: int
    phase: int = 1
    done: bool = False
    matches: list = dataclasses.field(default_factory=list)


class ServingEngine:
    def __init__(self, model: SpatioTemporalModel, embed_fn: Callable,
                 cfg: EngineConfig):
        self.model = model
        self.embed_fn = embed_fn
        self.cfg = cfg
        self.C = model.n_cams
        self.store = FrameStore(self.C, cfg.retention)
        self.queries: dict[int, QueryState] = {}
        self.t = 0
        self.frames_processed = 0
        self.ticks = 0
        self._S = np.asarray(model.S)
        self._cdf = np.asarray(model.cdf)
        self._f0 = np.asarray(model.f0)
        self._w_end1 = np.asarray(model.window_end(cfg.s_thresh, cfg.t_thresh))
        self._w_end2 = np.asarray(model.window_end(
            cfg.s_thresh / cfg.relax_factor, cfg.t_thresh / cfg.relax_factor))

    # -- query lifecycle --------------------------------------------------
    def submit_query(self, qid: int, feat: np.ndarray, cam: int, frame: int):
        self.queries[qid] = QueryState(qid, feat / max(np.linalg.norm(feat), 1e-9),
                                       cam, frame)

    def _admitted(self, q: QueryState, t: int) -> np.ndarray:
        cfg = self.cfg
        elapsed = t - q.f_q
        relax = cfg.relax_factor if q.phase >= 2 else 1.0
        s_th = cfg.s_thresh / relax
        t_th = cfg.t_thresh / relax
        b = np.clip(elapsed // self.model.bin_width, 0, self.model.n_bins - 1)
        arrived = self._cdf[q.c_q, :, max(b - 1, 0)] if b > 0 else 0.0
        mask = (self._S[q.c_q] >= s_th) & (elapsed >= self._f0[q.c_q]) & \
            (arrived <= 1.0 - t_th)
        if elapsed <= cfg.self_window:
            mask[q.c_q] = True
        return mask

    # -- per-tick ----------------------------------------------------------
    def ingest(self, frames_by_cam: dict[int, Any]):
        """New live frames at the current step (frame = detector crops)."""
        for cam, frame in frames_by_cam.items():
            self.store.append(cam, self.t, frame)

    def tick(self) -> dict:
        """One admission+inference round over the live step. Returns stats."""
        cfg = self.cfg
        wanted: dict[tuple[int, int], list[int]] = {}
        for q in self.queries.values():
            if q.done:
                continue
            mask = self._admitted(q, self.t)
            for cam in np.where(mask)[0]:
                wanted.setdefault((int(cam), self.t), []).append(q.qid)

        # dedup: each admitted frame embeds once (fleet batching win)
        batch_keys = [k for k in wanted if self.store.get(*k) is not None]
        stats = {"t": self.t, "admitted": len(wanted), "batched": len(batch_keys),
                 "matches": 0}
        for start in range(0, len(batch_keys), cfg.max_batch):
            keys = batch_keys[start:start + cfg.max_batch]
            crops, owners = [], []
            for key in keys:
                for crop in self.store.get(*key):
                    crops.append(crop)
                    owners.append(key)
            if not crops:
                continue
            emb = self.embed_fn(np.stack(crops))           # (n, D)
            emb = emb / np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)
            self.frames_processed += len(keys)
            for key, qids in ((k, wanted[k]) for k in keys):
                idx = [i for i, o in enumerate(owners) if o == key]
                if not idx:
                    continue
                gal = emb[idx]
                for qid in qids:
                    q = self.queries[qid]
                    s = gal @ q.feat
                    j = int(np.argmax(s))
                    if 1.0 - s[j] < cfg.match_thresh:
                        self._on_match(q, key[0], key[1], gal[j])
                        stats["matches"] += 1

        # escalation / termination
        for q in self.queries.values():
            if q.done:
                continue
            elapsed = self.t - q.f_q
            if q.phase == 1 and elapsed > min(self._w_end1[q.c_q], cfg.exit_t):
                q.phase = 2
            elif q.phase >= 2 and elapsed > min(self._w_end2[q.c_q], cfg.exit_t):
                q.done = True
        self.t += 1
        self.ticks += 1
        return stats

    def _on_match(self, q: QueryState, cam: int, t: int, feat: np.ndarray):
        a = self.cfg.feat_alpha
        q.feat = (1 - a) * q.feat + a * feat
        q.feat /= max(np.linalg.norm(q.feat), 1e-9)
        q.c_q, q.f_q, q.phase = cam, t, 1
        q.matches.append((cam, t))
