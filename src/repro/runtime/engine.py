"""The serving engine: ReXCam admission control over the inference plane.

Per tick (one wall step over all live camera streams):

  1. ALL active queries are gathered into one batched
     ``repro.core.policy.PhaseState`` and a single vectorized
     ``policy.admit`` call (jit, policy static) produces the (Q, C)
     admission mask — the same function, windows and phase machine the
     batched offline tracker runs, so the two planes cannot drift,
  2. admitted (camera, frame) pairs are deduplicated across queries (a
     frame is detected / embedded once no matter how many queries want it —
     the fleet-scale batching win),
  3. the batch runs through the backbone embed function and each query
     ranks its admitted galleries against its representation (argmin over
     camera-major order, the ``reid_topk`` kernel semantics),
  4. match outcomes feed ``policy.advance``: matches re-anchor to phase 1;
     a query whose phase-1 windows exhaust REWINDS its cursor to f_q + 1
     and replays retained frames out of the ``FrameStore`` ring buffer with
     relaxed thresholds (§5.3) — frames evicted past the retention window
     surface as ``replay_misses`` (the cold-storage fallback the paper
     mentions).

Replay pacing follows §5.3: a lagging query consumes
``policy.replay_speed * policy.replay_skip`` content steps per wall tick
(skip mode samples 1-in-k of them inside ``admit``), so fast-forward mode
catches back up to the live frontier at k x throughput.

The engine is deliberately backbone-agnostic: ``embed_fn(frames) ->
(n, D)`` may be a smoke-scale transformer from ``repro.models`` or the
simulator's feature oracle (tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.correlation import SpatioTemporalModel
from repro.core.policy import (PhaseState, SearchPolicy, admit, advance,
                               phase_windows)
from repro.runtime.stream_store import FrameStore

# effectively "never": the live engine terminates queries via exit_t /
# window exhaustion, not a simulation horizon
_NO_HORIZON = 2 ** 30


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-plane settings.  All *search* semantics live in ``policy`` —
    the same ``SearchPolicy`` the offline tracker takes."""

    policy: SearchPolicy = SearchPolicy()
    max_batch: int = 256
    retention: int = 600


@dataclasses.dataclass
class QueryState:
    qid: int
    feat: np.ndarray
    c_q: int
    f_q: int
    f_curr: int            # content frame the search cursor is on
    phase: int = 1
    done: bool = False
    matches: list = dataclasses.field(default_factory=list)
    rescued: int = 0       # matches made during replay (phase >= 2)
    replay_credit: float = 0.0  # fractional replay-round carry (ff pacing)


@partial(jax.jit, static_argnames=("policy",))
def _admit_jit(model, policy: SearchPolicy, state: PhaseState, geo_adj=None):
    return admit(model, policy, state, geo_adj)


@partial(jax.jit, static_argnames=("policy",))
def _advance_jit(policy: SearchPolicy, windows, state: PhaseState,
                 matched, match_cam):
    return advance(policy, windows, state, matched, match_cam, _NO_HORIZON)


class ServingEngine:
    def __init__(self, model: SpatioTemporalModel, embed_fn: Callable,
                 cfg: EngineConfig, geo_adj=None):
        self.model = model
        self.embed_fn = embed_fn
        self.cfg = cfg
        self.policy = cfg.policy
        self.C = model.n_cams
        # the geo baseline's proximity mask; all-ones when not provided
        # (same default as the tracker)
        self._geo_adj = jnp.asarray(
            geo_adj if geo_adj is not None else np.ones((self.C, self.C), bool))
        self.store = FrameStore(self.C, cfg.retention)
        self.queries: dict[int, QueryState] = {}
        self.t = 0
        self.frames_processed = 0
        self.replay_misses = 0       # replay reads past the retention window
        self.ticks = 0
        self._windows = phase_windows(model, cfg.policy)

    # -- query lifecycle --------------------------------------------------
    def submit_query(self, qid: int, feat: np.ndarray, cam: int, frame: int):
        self.queries[qid] = QueryState(
            qid, feat / max(np.linalg.norm(feat), 1e-9), cam, frame,
            f_curr=frame + 1)

    # -- batched state marshalling ---------------------------------------
    def _gather(self, qs: list[QueryState]) -> PhaseState:
        """Engine QueryStates -> one batched PhaseState.  The live frontier
        is the engine wall clock: frames through ``self.t`` are ingested.

        The batch is padded to the next power of two with ``done`` rows so
        the jitted admit/advance compile for O(log Q) shapes instead of one
        per live-query count (done rows admit nothing and never advance).
        """
        n = len(qs)
        N = 1 << max(n - 1, 0).bit_length()
        pad = N - n

        def col(vals, fill, dtype):
            return jnp.asarray(np.array(vals + [fill] * pad, dtype))

        return PhaseState(
            f_q=col([q.f_q for q in qs], 0, np.int32),
            c_q=col([q.c_q for q in qs], 0, np.int32),
            f_curr=col([q.f_curr for q in qs], 0, np.int32),
            phase=col([q.phase for q in qs], 1, np.int32),
            live_f=col([float(self.t)] * n, 0.0, np.float32),
            done=col([False] * n, True, np.bool_),
        )

    def _scatter(self, qs: list[QueryState], ps: PhaseState,
                 matched: np.ndarray, match_cam: np.ndarray, gals: list):
        """Write the advanced PhaseState back into the QueryState objects."""
        a = self.policy.feat_alpha
        f_q = np.asarray(ps.f_q)
        c_q = np.asarray(ps.c_q)
        f_curr = np.asarray(ps.f_curr)
        phase = np.asarray(ps.phase)
        done = np.asarray(ps.done)
        for i, q in enumerate(qs):
            if matched[i]:
                emb = gals[i][1]
                q.feat = (1 - a) * q.feat + a * emb
                q.feat /= max(np.linalg.norm(q.feat), 1e-9)
                if q.phase >= 2:
                    q.rescued += 1
                q.matches.append((int(match_cam[i]), int(q.f_curr)))
            q.f_q, q.c_q = int(f_q[i]), int(c_q[i])
            q.f_curr, q.phase = int(f_curr[i]), int(phase[i])
            q.done = bool(done[i])

    # -- per-tick ----------------------------------------------------------
    def ingest(self, frames_by_cam: dict[int, Any]):
        """New live frames at the current step (frame = detector crops)."""
        for cam, frame in frames_by_cam.items():
            self.store.append(cam, self.t, frame)

    def tick(self, record_trace: list | None = None) -> dict:
        """One admission+inference round over all live queries at once.

        A caught-up query consumes one content step; a replaying query
        consumes up to ``policy.replay_rate`` content steps (extra rounds),
        which is how fast-forward mode catches up.  Returns stats; pass a
        list as ``record_trace`` to collect (qid, f_curr, phase, mask) per
        processed round (the parity-test hook).
        """
        stats = {"t": self.t, "admitted": 0, "batched": 0, "matches": 0,
                 "replay_misses": 0}
        # Replay pacing: a lagging query earns policy.replay_rate content
        # rounds per wall tick, with the fractional remainder carried across
        # ticks so e.g. replay_speed=1.5 really averages 1.5x, matching the
        # tracker's continuous live_f model.  Caught-up queries get 1 round.
        budget = {}
        for q in self.queries.values():
            if q.done:
                continue
            if q.f_curr >= self.t:
                q.replay_credit = 0.0
                budget[q.qid] = 1
            else:
                q.replay_credit += self.policy.replay_rate
                rounds = int(q.replay_credit)
                q.replay_credit -= rounds
                budget[q.qid] = rounds
        while True:
            qs = [q for q in self.queries.values()
                  if not q.done and budget.get(q.qid, 0) > 0
                  and q.f_curr <= self.t]
            if not qs:
                break
            for q in qs:
                # live queries only get 1 content step per wall tick
                budget[q.qid] -= 1 if q.f_curr < self.t \
                    else budget[q.qid]
            self._round(qs, stats, record_trace)
        self.t += 1
        self.ticks += 1
        return stats

    def _round(self, qs: list[QueryState], stats: dict,
               trace: list | None) -> None:
        ps = self._gather(qs)
        mask = np.asarray(
            _admit_jit(self.model, self.policy, ps, self._geo_adj))  # (n, C)

        # dedup: each admitted (cam, frame) pair embeds once (fleet batching)
        wanted: dict[tuple[int, int], list[int]] = {}
        for i, q in enumerate(qs):
            for cam in np.flatnonzero(mask[i]):
                wanted.setdefault((int(cam), q.f_curr), []).append(i)
        stats["admitted"] += len(wanted)

        batch_keys, frames = [], {}
        for key in wanted:
            try:
                frame = self.store.get(*key)
            except KeyError:            # evicted: cold-storage miss (§5.3)
                self.replay_misses += 1
                stats["replay_misses"] += 1
                continue
            if frame is not None:
                batch_keys.append(key)
                frames[key] = frame
        stats["batched"] += len(batch_keys)

        key_emb: dict[tuple[int, int], np.ndarray] = {}
        for start in range(0, len(batch_keys), self.cfg.max_batch):
            keys = batch_keys[start:start + self.cfg.max_batch]
            crops, counts = [], []
            for key in keys:
                crops.extend(frames[key])
                counts.append(len(frames[key]))
            if not crops:
                continue
            emb = self.embed_fn(np.stack(crops))           # (n, D)
            emb = emb / np.maximum(
                np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)
            self.frames_processed += len(keys)
            pos = 0
            for key, n in zip(keys, counts):
                key_emb[key] = emb[pos:pos + n]
                pos += n

        # per-query ranking over its admitted galleries, camera-major order
        # (identical tie-breaking to the tracker's flat argmin); arrays span
        # the padded batch so advance sees matching shapes
        matched = np.zeros(mask.shape[0], bool)
        match_cam = np.zeros(mask.shape[0], np.int32)
        gals: list = [None] * len(qs)
        for i, q in enumerate(qs):
            cams, blocks = [], []
            for cam in np.flatnonzero(mask[i]):
                emb = key_emb.get((int(cam), q.f_curr))
                if emb is not None and len(emb):
                    cams.append(int(cam))
                    blocks.append(emb)
            if not blocks:
                continue
            gal = np.concatenate(blocks)
            d = 1.0 - gal @ q.feat
            j = int(np.argmin(d))
            if d[j] < self.policy.match_thresh:
                matched[i] = True
                sizes = np.cumsum([len(b) for b in blocks])
                match_cam[i] = cams[int(np.searchsorted(sizes, j, "right"))]
                gals[i] = (match_cam[i], gal[j])
                stats["matches"] += 1

        if trace is not None:
            for i, q in enumerate(qs):
                trace.append(dict(qid=q.qid, f_curr=q.f_curr, phase=q.phase,
                                  mask=mask[i].copy(), matched=bool(matched[i]),
                                  match_cam=int(match_cam[i])))

        ps_next = _advance_jit(self.policy, self._windows, ps,
                               jnp.asarray(matched), jnp.asarray(match_cam))
        self._scatter(qs, ps_next, matched, match_cam, gals)
