"""Multi-host gallery transport: the async fetch plane behind the gallery.

The serving fleet keeps embedding blocks device-resident on their owner
worker (``runtime.gallery.ShardedGalleryStore``).  On one host the owner's
buffer is directly addressable and a fetch is a zero-copy device read; at
the paper's simulated 130-camera scale the owner shards live on REMOTE
hosts and every fetch crosses the network — remote fetch latency sits
directly on the serving round's critical path.  This module is that fetch
plane, factored so the engines never know which one they run on:

* ``Transport`` — the contract: ``fetch_async(peer, key, payload_fn)``
  issues a fetch against an owner peer and returns a ``FetchHandle``;
  ``wait(handle)`` delivers the payload (or raises ``PeerDeadError`` once
  the retry budget is exhausted or the peer was marked dead); ``fetch`` is
  the blocking composition.  Per-peer counters (fetches / retries /
  timeouts) keep the fetch plane observable.
* ``InProcTransport`` — today's single-controller behavior: delivery is
  immediate and zero-copy (the payload thunk runs at ``wait`` time; no
  serialization snapshot is taken).
* ``FakeRpcTransport`` — remote owners modelled faithfully enough to
  develop and test against: per-peer injected latency / jitter / drop /
  reorder (``FaultProfile``), timeout + retry with exponential backoff,
  and a dead-peer signal (``on_dead``) the fleet wires into its
  quarantine-and-rehome machinery.  The fault schedule is DETERMINISTIC —
  every draw is seeded by a (seed, peer, key, attempt) hash, so a run
  replays exactly — and the clock/sleep pair is injectable
  (``manual_clock``) so tests advance virtual time instead of sleeping.
  The payload is snapshotted at issue time (serialize-at-send), the one
  semantic difference from the zero-copy in-proc path.
* ``PrefetchPipeline`` — the double buffer that hides fetch latency
  behind compute.  At the end of round N the engine speculates round
  N+1's admitted (camera, frame) keys — ``policy.advance`` has already
  produced the next cursors, so admission is re-evaluated on the advanced
  state under a no-new-information guess — and issues async fetches for
  the keys whose blocks are cache-resident.  Round N+1 consumes delivered
  blocks out of the buffer (``prefetch_hits``) and falls back to a
  blocking fetch on any misspeculation: a key never speculated, a block
  evicted between issue and use, or an owner that died mid-fetch
  (``prefetch_wasted`` accounts every discarded handle exactly).

Transport must never change WHAT is ranked, only WHEN it arrives:
delivered bytes are bit-identical to the in-proc device read, which is
what lets the fleet differential harness pin every transport/fault
configuration trace-identical to the single engine
(``tests/test_transport.py``).
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Callable

import numpy as np


class TransportError(RuntimeError):
    """Base class for fetch-plane failures."""


class PeerDeadError(TransportError):
    """The owner peer is unreachable: the retry budget is exhausted, or the
    peer was already marked dead (e.g. the fleet lost the worker while this
    fetch was in flight)."""

    def __init__(self, peer: str, detail: str = ""):
        super().__init__(f"peer {peer!r} is dead{': ' + detail if detail else ''}")
        self.peer = peer


def manual_clock(start: float = 0.0):
    """A (clock, sleep) pair over virtual time: ``sleep`` advances the clock
    instead of blocking, so fault-injection tests with seconds of injected
    latency run in microseconds.  Pass both into ``FakeRpcTransport``."""
    state = [float(start)]

    def clock() -> float:
        return state[0]

    def sleep(dt: float) -> None:
        state[0] += max(float(dt), 0.0)

    return clock, sleep


def _stable_hash(x: Any) -> int:
    """Process-stable 32-bit hash (python's ``hash`` is salted per run)."""
    return zlib.crc32(repr(x).encode())


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """Injected fault model for one peer (all times in seconds).

    ``latency`` is the base RTT of a successful fetch; ``jitter`` adds a
    uniform [0, jitter) extra; with probability ``drop`` an attempt is lost
    entirely (the requester only learns via its timeout); with probability
    ``reorder`` a response is held back ``reorder_delay`` extra seconds, so
    responses overtake each other (delivery order != issue order)."""

    latency: float = 0.0
    jitter: float = 0.0
    drop: float = 0.0
    reorder: float = 0.0
    reorder_delay: float = 0.0


@dataclasses.dataclass
class FetchHandle:
    """One in-flight fetch.  ``payload_fn`` (lazy, zero-copy) or
    ``payload`` (snapshot) carries the data; ``_sched`` caches the resolved
    fault schedule so counters tick exactly once per fetch."""

    peer: str
    key: Any
    issued_at: float
    payload_fn: Callable | None = None
    payload: Any = None
    _sched: Any = None

    def _deliver(self):
        return self.payload if self.payload_fn is None else self.payload_fn()


@dataclasses.dataclass
class LocalFetchHandle:
    """Handle for a transport-less gallery: ``wait_fetch`` re-reads the
    store directly (the degenerate immediate path)."""

    cam: int
    t: int


@dataclasses.dataclass
class _Schedule:
    """Resolved delivery schedule for one fetch: ``ready`` is the delivery
    time (None = every attempt failed), ``failed_at`` the time the final
    timeout fires when dead."""

    ready: float | None
    attempts: int
    retries: int
    timeouts: int
    failed_at: float


class Transport:
    """The fetch-plane contract the gallery programs to.

    ``on_dead(peer)`` fires exactly once, the first time a peer's retry
    budget exhausts — the fleet wires it to quarantine + gallery rehome so
    a blocked fetch can retry against the block's new owner.  ``mark_dead``
    is the external direction (the fleet lost a worker): in-flight handles
    to that peer fail fast at ``wait`` instead of timing out.
    """

    kind = "base"

    def __init__(self, on_dead: Callable[[str], None] | None = None):
        self.on_dead = on_dead
        self._dead: set[str] = set()
        self._peer_stats: dict[str, dict] = {}
        self.remote_fetches = 0
        self.retries = 0
        self.timeouts = 0
        # True while the on_dead callback runs — the rehome callback must
        # only re-home bookkeeping; issuing a fetch from inside it can
        # recurse through _fail_peer.  Asserted under REPRO_SANITIZE=1.
        self._in_on_dead = False

    # -- the contract ------------------------------------------------------
    def fetch_async(self, peer: str, key: Any,
                    payload_fn: Callable) -> FetchHandle:
        raise NotImplementedError

    def wait(self, handle: FetchHandle) -> Any:
        raise NotImplementedError

    def fetch(self, peer: str, key: Any, payload_fn: Callable) -> Any:
        """Blocking fetch: issue + wait."""
        return self.wait(self.fetch_async(peer, key, payload_fn))

    # -- peer liveness -----------------------------------------------------
    def is_dead(self, peer: str) -> bool:
        return peer in self._dead

    def mark_dead(self, peer: str) -> None:
        """External death notice (the fleet already removed the worker):
        fail this peer's fetches fast.  Does NOT fire ``on_dead`` — the
        caller is the rehome machinery itself."""
        self._dead.add(peer)

    def _fail_peer(self, peer: str) -> None:
        """Internal death discovery (retry budget exhausted): mark dead and
        fire the dead-peer signal exactly once."""
        if peer in self._dead:
            return
        self._dead.add(peer)
        if self.on_dead is not None:
            self._in_on_dead = True
            try:
                self.on_dead(peer)
            finally:
                self._in_on_dead = False

    def _check_reentry(self, op: str) -> None:
        """Under REPRO_SANITIZE=1: refuse fetch-plane entry from inside the
        dead-peer callback (re-home first, retry after it returns)."""
        if self._in_on_dead:
            from repro.analysis import sanitize
            if sanitize.enabled():
                raise AssertionError(
                    f"transport.{op} re-entered from inside the on_dead "
                    "callback — the rehome callback must not issue fetches "
                    "(the blocked fetch retries after it returns)")

    # -- accounting --------------------------------------------------------
    def _stats(self, peer: str) -> dict:
        if peer not in self._peer_stats:
            self._peer_stats[peer] = dict(fetches=0, retries=0, timeouts=0)
        return self._peer_stats[peer]

    def counters(self) -> dict:
        return dict(remote_fetches=self.remote_fetches, retries=self.retries,
                    timeouts=self.timeouts, dead_peers=len(self._dead))

    def peer_counters(self) -> dict[str, dict]:
        return {w: dict(st) for w, st in self._peer_stats.items()}


class InProcTransport(Transport):
    """Single-controller behavior, named: delivery is immediate and
    zero-copy (the payload thunk runs at ``wait``; nothing is snapshotted
    or serialized).  Counters still tick, so the fetch plane stays
    observable even before any remote peers exist."""

    kind = "inproc"

    def fetch_async(self, peer, key, payload_fn):
        self._check_reentry("fetch_async")
        if peer in self._dead:
            raise PeerDeadError(peer, "fetch issued to a dead peer")
        self.remote_fetches += 1
        self._stats(peer)["fetches"] += 1
        return FetchHandle(peer=peer, key=key, issued_at=0.0,
                           payload_fn=payload_fn)

    def wait(self, handle):
        self._check_reentry("wait")
        if handle.peer in self._dead:
            raise PeerDeadError(handle.peer, "peer died while fetch in flight")
        return handle._deliver()


class FakeRpcTransport(Transport):
    """Remote owners with injected faults, deterministic and clock-injectable.

    ``faults`` maps peer -> ``FaultProfile`` (``default`` covers unlisted
    peers).  Retry-with-backoff arithmetic: attempt k (0-based) is issued,
    and if it is dropped or its delivery would land past ``timeout``, the
    requester waits out the timeout, backs off ``backoff * 2**k``, and
    re-issues; after ``max_retries`` re-issues the peer is declared dead
    (``on_dead`` fires, ``PeerDeadError`` raises).  Every random draw is
    seeded by (seed, peer, key, attempt), so the schedule for a given fetch
    is a pure function — reorder under concurrency, but bit-reproducible.
    """

    kind = "fake_rpc"

    def __init__(self, faults: dict[str, FaultProfile] | None = None, *,
                 default: FaultProfile = FaultProfile(),
                 timeout: float = 1.0, max_retries: int = 3,
                 backoff: float = 0.05, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 on_dead: Callable[[str], None] | None = None):
        super().__init__(on_dead=on_dead)
        if timeout <= 0:
            raise ValueError(f"timeout={timeout} must be > 0 (a dropped "
                             f"attempt is only detected by its timeout)")
        self.faults = dict(faults or {})
        self.default = default
        self.timeout = timeout
        self.max_retries = max_retries
        self.backoff = backoff
        self.seed = seed
        self._clock = clock
        self._sleep = sleep

    def profile(self, peer: str) -> FaultProfile:
        return self.faults.get(peer, self.default)

    def _draws(self, peer: str, key: Any, attempt: int) -> np.ndarray:
        rng = np.random.default_rng(
            [self.seed, _stable_hash(peer), _stable_hash(key), attempt])
        return rng.random(3)

    def _schedule(self, peer: str, key: Any, issued_at: float) -> _Schedule:
        """Resolve the full (deterministic) fate of one fetch: attempt
        times, drops, timeouts, backoffs, and either a delivery time or the
        time the final timeout declares the peer dead."""
        prof = self.profile(peer)
        t = issued_at
        retries = timeouts = 0
        for attempt in range(self.max_retries + 1):
            r = self._draws(peer, key, attempt)
            if r[0] >= prof.drop:               # the attempt got through
                delay = prof.latency + prof.jitter * r[1]
                if r[2] < prof.reorder:
                    delay += prof.reorder_delay
                if delay <= self.timeout:
                    return _Schedule(ready=t + delay, attempts=attempt + 1,
                                     retries=retries, timeouts=timeouts,
                                     failed_at=t + delay)
            # dropped, or delivery past the deadline: wait out the timeout
            timeouts += 1
            if attempt < self.max_retries:
                retries += 1
                t += self.timeout + self.backoff * (2 ** attempt)
        return _Schedule(ready=None, attempts=self.max_retries + 1,
                         retries=retries, timeouts=timeouts,
                         failed_at=t + self.timeout)

    def fetch_async(self, peer, key, payload_fn):
        self._check_reentry("fetch_async")
        if peer in self._dead:
            raise PeerDeadError(peer, "fetch issued to a dead peer")
        self.remote_fetches += 1
        self._stats(peer)["fetches"] += 1
        # serialize-at-send: the RPC payload is a snapshot taken at issue
        return FetchHandle(peer=peer, key=key, issued_at=self._clock(),
                           payload=payload_fn())

    def _sleep_until(self, t: float) -> None:
        dt = t - self._clock()
        if dt > 0:
            self._sleep(dt)

    def wait(self, handle):
        self._check_reentry("wait")
        if handle.peer in self._dead:
            raise PeerDeadError(handle.peer, "peer died while fetch in flight")
        sched = handle._sched
        if sched is None:
            sched = handle._sched = self._schedule(handle.peer, handle.key,
                                                   handle.issued_at)
            st = self._stats(handle.peer)
            st["retries"] += sched.retries
            st["timeouts"] += sched.timeouts
            self.retries += sched.retries
            self.timeouts += sched.timeouts
        if sched.ready is None:
            self._sleep_until(sched.failed_at)
            self._fail_peer(handle.peer)
            raise PeerDeadError(
                handle.peer, f"retry budget exhausted "
                f"({sched.attempts} attempts, {sched.timeouts} timeouts)")
        self._sleep_until(sched.ready)
        return handle._deliver()


class PrefetchPipeline:
    """Double-buffered speculative fetch over a ``FrameStore``-fronted
    gallery: ``issue`` starts async fetches for the NEXT round's predicted
    keys while the current round's blocks are being consumed; ``consume``
    serves a delivered block (validating the key is still cached — a block
    evicted between issue and use is discarded, never served stale) and
    returns None on any miss so the caller falls back to the blocking
    path.  ``prefetch_hits`` / ``prefetch_wasted`` on the gallery account
    every handle exactly: consumed, or discarded (evicted / dead owner /
    stale in ``sweep``)."""

    def __init__(self, store):
        self.store = store              # runtime.stream_store.FrameStore
        self._inflight: dict[Any, Any] = {}

    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    def issue(self, keys) -> int:
        """Start async fetches for every cached, not-already-in-flight key.
        Returns the number of fetches actually issued."""
        n = 0
        for key in keys:
            if key in self._inflight:
                continue
            try:
                h = self.store.fetch_emb_async(*key)
            except PeerDeadError:       # owner already dead: nothing to hide
                continue
            if h is not None:
                self._inflight[key] = h
                n += 1
        return n

    def consume(self, cam: int, t: int):
        """The prefetched block for (cam, t), or None (not speculated /
        evicted since issue / owner died mid-fetch) — the caller falls back
        to the blocking fetch, which re-resolves ownership."""
        h = self._inflight.pop((cam, t), None)
        if h is None:
            return None
        g = self.store.gallery
        if not self.store.emb_cached(cam, t):   # evicted between issue & use
            g.prefetch_wasted += 1
            return None
        try:
            emb = self.store.wait_emb(h)
        except PeerDeadError:                   # mid-fetch worker loss
            g.prefetch_wasted += 1
            return None
        if emb is None:
            g.prefetch_wasted += 1
            return None
        g.prefetch_hits += 1
        g.hits += 1       # counter parity with the blocking get path
        return emb

    def sweep(self) -> int:
        """Drop in-flight handles whose block got evicted (stale
        speculation) so the buffer stays bounded by the cache size.
        Returns the number dropped."""
        g = self.store.gallery
        stale = [k for k in self._inflight if not self.store.emb_cached(*k)]
        for k in stale:
            del self._inflight[k]
            g.prefetch_wasted += 1
        return len(stale)
