"""Sharded serving fleet: ``shard_map`` over the live query axis.

The scaling companion paper's deployment shape (and this repo's ROADMAP
"sharded serving" item): cross-camera inference spreads across a worker
fleet while the tiny correlation model M stays replicated on every worker.
``ShardedServingEngine`` realizes that split on a jax device mesh:

  * the batched ``PhaseState`` (the per-query search state) is SHARDED over
    the mesh's data axis — each worker owns a contiguous block of query
    rows, padded per shard to a uniform power of two,
  * M, the phase windows, the geo adjacency and the per-round deduplicated
    gallery are REPLICATED (a few small dense arrays — the paper's §7 point
    that the control plane's only persistent state is tiny),
  * the EMBEDDING plane is fleet-shared: by default the fleet injects a
    ``runtime.gallery.ShardedGalleryStore`` behind its ``FrameStore``, so
    the (camera, frame) embedding cache is partitioned over the same data
    axis (camera-hash owner shards, blocks resident on the owner's device)
    instead of replicated per process — one gallery for the whole fleet,
    and fleet-global embed calls match the single engine's exactly (no
    per-shard re-embedding),
  * every device round runs the SAME step bodies as the single-process
    ``ServingEngine`` (``policy.admit``, ``engine.rank_advance_round``)
    wrapped in ``parallel.compat.shard_map`` — so the fleet is
    trace-identical to one engine by construction, which the differential
    harness in ``tests/test_sharded_engine.py`` pins down.

Host-side placement is the control plane's job: queries are placed on the
least-loaded worker at submit time (O(1): per-worker live-query counters
are maintained on submit / completion / rebalance, not recounted by
scanning the placement map), and ``lose_worker`` shrinks the data axis via
``runtime.cluster.ElasticMesh`` (largest surviving grid, shardings rebuilt),
re-scatters ONLY the orphaned queries AND re-homes the lost worker's
gallery shards onto the survivors — an elastic scale-down, not a restart.
An optional ``HeartbeatMonitor`` drives the same path from
liveness/straggler signals via ``poll_health``.

Because admission, ranking and the phase machine are pure per-query maps
(the gallery is shared, not recomputed), placement never changes results —
worker loss mid-run keeps the trace bit-identical.  What sharding buys is
capacity: each worker ranks only its block of queries against the round's
gallery, and holds only its cameras' slice of the embedding cache.
"""
from __future__ import annotations

from typing import Iterable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.policy import admit, admit_tiles
from repro.parallel.compat import shard_map
from repro.runtime.cluster import ElasticMesh, HeartbeatMonitor
from repro.runtime.engine import (EngineConfig, QueryState, RoundPlan,
                                  ServingEngine, _pow2, advance_round,
                                  rank_advance_round, rank_advance_round_seg,
                                  rank_advance_round_tiles)
from repro.runtime.gallery import (GalleryStore, LocalGalleryStore,
                                   ShardedGalleryStore)


def make_sharded_step_fns(mesh, policy, topk: int, topk_rerank: bool = False,
                          n_cams: int = 0):
    """The fleet's six jitted shard_map step bodies for ``mesh`` — query
    rows shard over the data axis, model/windows/gallery ride replicated.
    Returned as (admit, rank_advance, rank_advance_seg, advance,
    admit_tiles, rank_advance_tiles); the segment variant is the
    consolidated round's ONE ranking pass, with the per-query segment ids
    sharding alongside the state rows and the gallery's segment tags
    replicated like its cam/frame tags; the tile pair refines camera
    admission to fused (camera, tile) cells — the (Q, C*T*T) mask shards
    with the state rows, the gallery's cell tags replicate.
    Module-level (not a method) so the static invariant plane
    (``repro.analysis``) can trace and audit the EXACT jaxprs the fleet
    dispatches, on any mesh."""
    Pd, Pr = P("data"), P()

    def _admit(model, state, geo_adj):
        return admit(model, policy, state, geo_adj)

    def _admit_tiles(model, state, geo_adj, tile_q):
        return admit_tiles(model, policy, state, geo_adj, tile_q)

    def _rank_advance(windows, state, q_feat, mask, gal, gal_cam, gal_frame):
        return rank_advance_round(policy, windows, state, q_feat, mask, gal,
                                  gal_cam, gal_frame, topk, topk_rerank)

    def _rank_advance_seg(windows, state, q_feat, q_seg, mask, gal, gal_cam,
                          gal_frame, gal_seg):
        return rank_advance_round_seg(policy, windows, state, q_feat, q_seg,
                                      mask, gal, gal_cam, gal_frame, gal_seg,
                                      topk, topk_rerank)

    def _rank_advance_tiles(windows, state, q_feat, q_seg, mask_ct, gal,
                            gal_ct, gal_cam, gal_frame, gal_seg):
        return rank_advance_round_tiles(policy, windows, state, q_feat,
                                        q_seg, mask_ct, gal, gal_ct, gal_cam,
                                        gal_frame, gal_seg, topk, n_cams,
                                        topk_rerank)

    def _advance(windows, state):
        return advance_round(policy, windows, state)

    return (
        jax.jit(shard_map(_admit, mesh=mesh,
                          in_specs=(Pr, Pd, Pr), out_specs=Pd,
                          check_vma=False)),
        jax.jit(shard_map(_rank_advance, mesh=mesh,
                          in_specs=(Pr, Pd, Pd, Pd, Pr, Pr, Pr),
                          out_specs=(Pd,) * 8,
                          check_vma=False)),
        jax.jit(shard_map(_rank_advance_seg, mesh=mesh,
                          in_specs=(Pr, Pd, Pd, Pd, Pd, Pr, Pr, Pr, Pr),
                          out_specs=(Pd,) * 8,
                          check_vma=False)),
        jax.jit(shard_map(_advance, mesh=mesh,
                          in_specs=(Pr, Pd), out_specs=Pd,
                          check_vma=False)),
        jax.jit(shard_map(_admit_tiles, mesh=mesh,
                          in_specs=(Pr, Pd, Pr, Pd), out_specs=(Pd, Pd),
                          check_vma=False)),
        jax.jit(shard_map(_rank_advance_tiles, mesh=mesh,
                          in_specs=(Pr, Pd, Pd, Pd, Pd, Pr, Pr, Pr, Pr, Pr),
                          out_specs=(Pd,) * 8,
                          check_vma=False)),
    )


class ShardedServingEngine(ServingEngine):
    """A serving fleet: one controller, ``n_shards`` workers, one trace."""

    def __init__(self, model, embed_fn, cfg: EngineConfig, geo_adj=None, *,
                 shards: int | None = None, devices: Iterable | None = None,
                 monitor: HeartbeatMonitor | None = None,
                 cluster: ElasticMesh | None = None):
        devs = list(devices if devices is not None else jax.devices())
        if shards is not None:
            if shards < 1 or shards > len(devs):
                raise ValueError(
                    f"shards={shards} infeasible: {len(devs)} devices visible")
            devs = devs[:shards]
        if monitor is not None:
            # fail loudly at construction, not as a silent poll_health no-op:
            # every fleet worker id must be a name the monitor tracks
            missing = [f"w{i}" for i in range(len(devs))
                       if f"w{i}" not in monitor.workers]
            if missing:
                raise ValueError(
                    f"HeartbeatMonitor does not track fleet workers "
                    f"{missing} — fleet worker ids are 'w0'..'w{len(devs)-1}'")
        # stable worker identities: position in the ORIGINAL device list.
        # Topology must exist before super().__init__ — the base constructor
        # calls _make_gallery(), and the fleet's gallery shards over it.
        self._device_of = {f"w{i}": d for i, d in enumerate(devs)}
        self._all_workers = list(self._device_of)
        self._workers = list(self._all_workers)        # live, data-axis order
        super().__init__(model, embed_fn, cfg, geo_adj=geo_adj)
        self.cluster = cluster or ElasticMesh(model_parallel=1)
        self.monitor = monitor
        self._placement: dict[int, str] = {}           # qid -> worker
        # O(1) placement: live (not-done) query count per worker, maintained
        # on submit_query / _on_query_done / lose_worker — never recounted
        # by scanning the placement map
        self._live_load = {w: 0 for w in self._all_workers}
        # query_rounds = per-query rounds DISPATCHED for this worker's
        # queries (not engine ticks; skip-mode rounds short-circuited on
        # the host are charged to content_steps but never reach a worker,
        # so sum(query_rounds) == content_steps - skipped_steps).
        # unique_frames is the worker's shard-LOCAL deduplicated demand;
        # owned_frames is its slice of the fleet-GLOBAL dedup set (which
        # camera-owner would serve each deduplicated frame) — the two cost
        # views the gallery plane distinguishes.
        self._shard_stats = {w: dict(admitted_steps=0, unique_frames=0,
                                     owned_frames=0, query_rounds=0)
                             for w in self._all_workers}
        self.rebalances = 0
        self._block_hwm = 1          # per-shard batch rows high-water mark
        # transport dead-peer signal: a fetch whose retry budget exhausts
        # mid-round re-homes the gallery IMMEDIATELY (so the blocked fetch
        # can retry against the new owner) and defers the full mesh
        # scale-down to the end of the tick (the mesh must not shrink while
        # a round's shard_map dispatch is in flight)
        self._pending_loss: list[str] = []
        tr = getattr(self.gallery, "transport", None)
        if tr is not None:
            tr.on_dead = self._on_transport_dead
        self._refresh_mesh()

    # -- the gallery plane -------------------------------------------------
    def _make_gallery(self) -> GalleryStore:
        """gallery="auto"/"sharded": ONE fleet-wide embedding plane,
        partitioned over the data axis (camera-hash owner shards, blocks on
        the owner's device).  gallery="local" keeps the replicated-baseline
        semantics (a private host-side cache, as if each engine re-embedded
        for itself) — what ``gallery_sweep`` compares against."""
        if self.cfg.gallery in ("auto", "sharded"):
            return ShardedGalleryStore(self.C, self.cfg.retention,
                                       self._all_workers, self._device_of,
                                       transport=self.cfg.transport)
        if self.cfg.gallery == "local":
            if self.cfg.transport is not None:
                raise ValueError(
                    "transport= requires the sharded gallery "
                    "(gallery='auto'/'sharded'): the replicated-local "
                    "baseline has no remote owners to fetch from")
            return LocalGalleryStore(self.C, self.cfg.retention)
        raise ValueError(f"unknown gallery mode {self.cfg.gallery!r} "
                         f"(expected 'auto', 'local' or 'sharded')")

    def gallery_report(self) -> dict:
        rep = super().gallery_report()
        if isinstance(self.gallery, ShardedGalleryStore):
            rep["per_worker"] = self.gallery.per_worker_report()
        return rep

    # -- fleet topology ----------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self._workers)

    def _refresh_mesh(self) -> None:
        """(Re)build the mesh over the surviving workers: the data axis
        shrinks to the live count (``ElasticMesh.grid_for``), and the cached
        shard_map callables are invalidated so the next round lowers onto
        the new grid.  The replicated control-plane state (M + the phase
        windows) is re-committed to the new mesh so a fleet that already
        hot-swapped its model never dispatches arrays committed to a dead
        device."""
        if not self._workers:
            raise RuntimeError("serving fleet has no surviving workers")
        self.mesh = self.cluster.make_mesh(
            [self._device_of[w] for w in self._workers])
        self._shard_of = {w: i for i, w in enumerate(self._workers)}
        self._sharded_fns = None
        self._replicate_control_plane()

    def _replicate_control_plane(self) -> None:
        """Commit M and the phase windows replicated onto every shard of the
        CURRENT mesh — one transfer at swap/re-mesh time instead of an
        implicit broadcast on every dispatch."""
        rep = NamedSharding(self.mesh, P())
        self.model = jax.device_put(self.model, rep)
        self._windows = jax.device_put(self._windows, rep)

    def swap_model(self, model) -> int:
        """Fleet hot-swap: the base swap (atomic between rounds — the
        mid-round guard is what makes 'every shard sees one M per round'
        hold), then the new M/windows are re-replicated onto every live
        shard of the mesh in one device_put.  The shard_map callables are
        untouched: M rides in as a replicated ARGUMENT, so a swap never
        recompiles or re-lowers the step bodies."""
        epoch = super().swap_model(model)
        self._replicate_control_plane()
        return epoch

    def _load(self, worker: str) -> int:
        """Live (not-done) queries placed on ``worker`` — O(1), from the
        maintained counters (equal to scanning the placement map, which the
        load-accounting test pins)."""
        return self._live_load.get(worker, 0)

    def _least_loaded(self) -> str:
        return min(self._workers, key=lambda w: (self._load(w),
                                                 self._shard_of[w]))

    def submit_query(self, qid: int, feat, cam: int, frame: int):
        if qid in self._placement:     # resubmission: retire the old count
            old = self._placement[qid]
            q_old = self.queries.get(qid)
            if q_old is not None and not q_old.done:
                self._live_load[old] -= 1
        super().submit_query(qid, feat, cam, frame)
        w = self._least_loaded()
        self._placement[qid] = w
        self._live_load[w] += 1

    def _on_query_done(self, q: QueryState) -> None:
        self._live_load[self._placement[q.qid]] -= 1

    def lose_worker(self, worker: str | int) -> list[int]:
        """Elastic scale-down: drop one worker, shrink the data axis,
        re-scatter its orphaned queries over the survivors (least-loaded
        first, round-robin via ``ElasticMesh.rebalance_streams``) and
        re-home its gallery shards (camera ownership + device-resident
        blocks migrate; the shared cache survives the worker).  Returns
        the re-placed qids."""
        w = f"w{worker}" if isinstance(worker, int) else worker
        if w not in self._workers:
            raise KeyError(f"{w!r} is not a live worker (live: {self._workers})")
        if len(self._workers) == 1:
            raise RuntimeError("cannot lose the last worker of the fleet")
        self._workers.remove(w)
        self._refresh_mesh()
        tr = getattr(self.gallery, "transport", None)
        if tr is not None:
            # in-flight fetches (prefetch handles included) to the lost
            # worker now fail fast with PeerDeadError instead of timing out
            tr.mark_dead(w)
        if isinstance(self.gallery, ShardedGalleryStore):
            self.gallery.rehome(w, list(self._workers))
        orphans = sorted(qid for qid, pw in self._placement.items() if pw == w)
        self._live_load[w] = 0
        targets = sorted(self._workers,
                         key=lambda t: (self._load(t), self._shard_of[t]))
        for tw, group in zip(targets,
                             self.cluster.rebalance_streams(orphans,
                                                            len(targets))):
            for qid in group:
                self._placement[qid] = tw
                q = self.queries.get(qid)
                if q is not None and not q.done:
                    self._live_load[tw] += 1
        self.rebalances += 1
        return orphans

    def _on_transport_dead(self, w: str) -> None:
        """The transport's dead-peer signal: a fetch to ``w`` exhausted its
        retry budget.  Mid-round the mesh cannot shrink (a shard_map
        dispatch may be in flight), but the gallery CAN re-home immediately
        — ownership remapping touches no mesh state, and it is exactly what
        lets the blocked fetch retry against the block's new owner instead
        of failing the round.  The full scale-down (mesh shrink + orphan
        re-scatter) runs at the end of the tick."""
        if w not in self._workers or len(self._workers) == 1:
            return
        if self.monitor is not None and w in self.monitor.workers:
            self.monitor.quarantine(w)
        if self._in_round:
            if w not in self._pending_loss:
                self._pending_loss.append(w)
                self.gallery.rehome(
                    w, [x for x in self._workers if x != w])
        else:
            self.lose_worker(w)

    def tick(self, record_trace: list | None = None) -> dict:
        stats = super().tick(record_trace)
        # drain transport-discovered worker deaths: the gallery already
        # re-homed mid-round; now the mesh shrinks and queries re-scatter
        # (lose_worker's own rehome is a no-op — ownership moved already)
        while self._pending_loss:
            w = self._pending_loss.pop(0)
            if w in self._workers and len(self._workers) > 1:
                self.lose_worker(w)
        return stats

    def poll_health(self) -> list[str]:
        """Drive elastic scale-down from the HeartbeatMonitor: dead workers
        and (quarantined) stragglers leave the fleet, their queries
        re-scatter.  No-op without a monitor."""
        if self.monitor is None:
            return []
        removed = []
        for w in self.monitor.stragglers():
            # quarantine only workers this fleet actually removes — the
            # monitor may track a superset, and the last worker stays
            if w in self._workers and len(self._workers) > 1:
                self.monitor.quarantine(w)
                self.lose_worker(w)
                removed.append(w)
        for w in self.monitor.dead():
            if w in self._workers and len(self._workers) > 1:
                self.lose_worker(w)
                removed.append(w)
        return removed

    # -- sharded layout + dispatch ----------------------------------------
    def _layout(self, qs: list[QueryState]) -> tuple[int, np.ndarray]:
        """Group batch rows by worker placement: shard s owns rows
        [s*block, (s+1)*block) with block a fleet-uniform power of two, so
        ``shard_map`` splits the padded batch into exactly the host-side
        placement.  Padding rows are ``done`` (admit nothing, rank to
        (NEG_INF, -1)) just like the single engine's."""
        groups: list[list[int]] = [[] for _ in self._workers]
        for i, q in enumerate(qs):
            groups[self._shard_of[self._placement[q.qid]]].append(i)
        block = _pow2(max(max((len(g) for g in groups), default=0), 1))
        # shard-block high-water mark: a shrinking cohort keeps the compiled
        # per-shard block (padding rows are done), so steady state never
        # mints a smaller shard_map signature (RecompileGuard's contract)
        self._block_hwm = max(self._block_hwm, block)
        block = self._block_hwm
        slots = np.zeros(len(qs), np.int64)
        for s, g in enumerate(groups):
            slots[g] = s * block + np.arange(len(g))
        return len(self._workers) * block, slots

    def prime_batch(self, n_queries: int) -> None:
        """Fleet variant of the single engine's ``prime_batch``: pre-size
        the per-shard block for ``n_queries`` spread over the current
        workers (balanced placement; a later imbalance can still grow the
        block, which the guard's one-new-signature allowance covers)."""
        per = -(-max(int(n_queries), 1) // max(len(self._workers), 1))
        self._block_hwm = max(self._block_hwm, _pow2(per))

    def _fns(self):
        """shard_map-wrapped step bodies for the CURRENT mesh (lazily built;
        invalidated on every elastic re-mesh).  State rows shard over the
        data axis; model/windows/geo/gallery ride along replicated."""
        if self._sharded_fns is None:
            self._sharded_fns = make_sharded_step_fns(
                self.mesh, self.policy, self.cfg.topk,
                topk_rerank=self.cfg.topk_rerank, n_cams=self.C)
        return self._sharded_fns

    def _dispatch_admit(self, ps):
        return self._fns()[0](self.model, ps, self._geo_adj)

    def _dispatch_admit_tiles(self, ps, tile_q):
        return self._fns()[4](self.model, ps, self._geo_adj, tile_q)

    def _dispatch_rank_advance(self, ps, q_feat, mask, gallery, gal_cam,
                               gal_frame):
        return self._fns()[1](self._windows, ps, q_feat, mask, gallery,
                              gal_cam, gal_frame)

    def _dispatch_rank_advance_seg(self, ps, q_feat, q_seg, mask, gallery,
                                   gal_cam, gal_frame, gal_seg):
        return self._fns()[2](self._windows, ps, q_feat, q_seg, mask,
                              gallery, gal_cam, gal_frame, gal_seg)

    def _dispatch_rank_advance_tiles(self, ps, q_feat, q_seg, mask_ct,
                                     gallery, gal_ct, gal_cam, gal_frame,
                                     gal_seg):
        return self._fns()[5](self._windows, ps, q_feat, q_seg, mask_ct,
                              gallery, gal_ct, gal_cam, gal_frame, gal_seg)

    def _dispatch_advance(self, ps):
        return self._fns()[3](self._windows, ps)

    # -- per-shard cost accounting ----------------------------------------
    def _account_round(self, plan: RoundPlan) -> None:
        """Per-worker view of the round, in BOTH cost conventions the
        gallery plane distinguishes: ``unique_frames`` is the worker's
        shard-LOCAL deduplicated (cam, frame) demand — what it would embed
        if every worker kept a private replicated cache; ``owned_frames``
        is the worker's slice of ``plan.work``, the round's fleet-GLOBAL
        dedup set (the frames whose camera it owns in the sharded
        gallery), which tiles the engine's ``unique_frames`` exactly."""
        qs, cams_by_q = plan.qs, plan.cams_by_q
        by_worker: dict[str, list[int]] = {}
        for i, q in enumerate(qs):
            by_worker.setdefault(self._placement[q.qid], []).append(i)
        for w, idxs in by_worker.items():
            st = self._shard_stats[w]
            st["query_rounds"] += len(idxs)
            st["admitted_steps"] += sum(len(cams_by_q[i]) for i in idxs)
            pairs = {(int(cam), qs[i].f_curr)
                     for i in idxs for cam in cams_by_q[i]}
            st["unique_frames"] += len(pairs)
        if isinstance(self.gallery, ShardedGalleryStore):
            # plan.work is already camera-major sorted, so owned_frames
            # counts never depend on hash-iteration order
            for cam, _f in plan.work:
                owner = self.gallery.owner_of(cam)
                self._shard_stats[owner]["owned_frames"] += 1

    def shard_report(self) -> list[dict]:
        """One row per worker (including lost ones, stats frozen): placement
        load and the cost conventions — ``admitted_steps`` (tiles the engine
        total), ``unique_frames`` (shard-local demand: what a replicated
        per-worker cache would embed) and ``owned_frames`` (the worker's
        slice of the fleet-global dedup set; sums to the engine's
        ``unique_frames`` when the gallery is sharded)."""
        live = set(self._workers)
        rows = [dict(worker=w, alive=w in live,
                     queries=self._load(w) if w in live else 0,
                     **self._shard_stats[w])
                for w in self._all_workers]
        if getattr(self.gallery, "transport", None) is not None:
            # fetch-plane traffic per owner peer: prefetch efficiency and
            # fault pressure are observable per worker
            per_w = self.gallery.per_worker_report()
            for row in rows:
                st = per_w[row["worker"]]
                row["remote_fetches"] = st["remote_fetches"]
                row["retries"] = st["retries"]
                row["timeouts"] = st["timeouts"]
        return rows
