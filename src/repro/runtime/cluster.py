"""Cluster health: heartbeats, straggler quarantine, elastic re-meshing.

Mirrors the paper's §7 fault-tolerance design at datacenter scale: cameras
(here: workers/hosts) heartbeat to the controller; the controller's only
persistent state is the (tiny, replicated) correlation model, so failover is
re-subscription, not recovery.  ``ElasticMesh`` shrinks the data axis to the
largest feasible grid when workers are lost and rebuilds shardings — elastic
scale-down without a full restart; lost stream assignments are rebalanced.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class WorkerState:
    last_seen: float
    # None = no latency sample yet; a real 0.0 first sample must NOT be
    # treated as "unset" (it would re-seed the EWMA on the next report)
    latency_ewma: float | None = None
    quarantined: bool = False


class HeartbeatMonitor:
    """Tracks liveness + per-tick latency; flags stragglers at k x median."""

    def __init__(self, workers: list[str], timeout: float = 10.0,
                 straggler_factor: float = 3.0, ewma: float = 0.3,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.ewma = ewma
        now = clock()
        self.workers = {w: WorkerState(last_seen=now) for w in workers}

    def heartbeat(self, worker: str, tick_latency: float | None = None):
        st = self.workers[worker]
        st.last_seen = self._clock()
        if tick_latency is not None:
            if st.latency_ewma is None:     # explicit first-sample seed
                st.latency_ewma = float(tick_latency)
            else:
                st.latency_ewma = (self.ewma * tick_latency +
                                   (1 - self.ewma) * st.latency_ewma)

    def dead(self) -> list[str]:
        now = self._clock()
        return [w for w, st in self.workers.items()
                if now - st.last_seen > self.timeout]

    def stragglers(self) -> list[str]:
        lat = np.array([st.latency_ewma for st in self.workers.values()
                        if st.latency_ewma is not None])
        if len(lat) < 2:
            return []
        med = float(np.median(lat))
        return [w for w, st in self.workers.items()
                if st.latency_ewma is not None
                and st.latency_ewma > self.straggler_factor * max(med, 1e-9)
                and not st.quarantined]

    def quarantine(self, worker: str):
        self.workers[worker].quarantined = True

    def active(self) -> list[str]:
        dead = set(self.dead())
        return [w for w, st in self.workers.items()
                if not st.quarantined and w not in dead]


class ElasticMesh:
    """Pick the largest (data, model) grid fitting the live device count.

    The model axis is pinned (tensor-parallel degree is a property of the
    model's sharding); the data axis shrinks to the largest multiple that
    the surviving devices support.  Streams/batches rebalance onto the new
    data axis; training resumes from the latest checkpoint reshard.
    """

    def __init__(self, model_parallel: int):
        self.model_parallel = model_parallel

    def grid_for(self, n_devices: int) -> tuple[int, int]:
        data = n_devices // self.model_parallel
        if data < 1:
            raise RuntimeError(
                f"{n_devices} devices cannot host model-parallel "
                f"degree {self.model_parallel}")
        return data, self.model_parallel

    def make_mesh(self, devices=None):
        import jax
        from jax.sharding import Mesh

        devices = list(devices if devices is not None else jax.devices())
        data, model = self.grid_for(len(devices))
        usable = np.asarray(devices[: data * model]).reshape(data, model)
        return Mesh(usable, ("data", "model"))

    def rebalance_streams(self, streams: list[int], n_shards: int) -> list[list[int]]:
        """Round-robin camera streams over the surviving data shards."""
        out: list[list[int]] = [[] for _ in range(n_shards)]
        for i, s in enumerate(streams):
            out[i % n_shards].append(s)
        return out
