"""The gallery/embedding plane: one feature store behind the engines.

The paper keeps "the last few minutes" of video hot (§5.3); its scaling
companion (Jain et al., *Scaling Video Analytics Systems to Large Camera
Deployments*) argues cross-camera workloads should SHARE inference state
across workers instead of recomputing it per process.  This module is that
shared state: the (camera, frame) -> embedding-block cache the serving
engines consult before calling ``embed_fn``, extracted out of ``FrameStore``
so one fleet can put a single gallery plane behind every engine.

Two implementations of one ``GalleryStore`` contract:

* ``LocalGalleryStore`` — host-resident per-camera dicts, exactly the
  per-engine semantics ``FrameStore`` used to hard-code.  The single-process
  engine's default, and the fleet's "replicated baseline" mode.
* ``ShardedGalleryStore`` — the (camera, frame) key space partitioned over
  the fleet's data axis: each camera hashes to one OWNER worker, and that
  camera's embedding blocks live on the owner's device (``jax.device_put``),
  row-padded to a power of two like the engines' round galleries so device
  buffer shapes stay bounded.  Hit/miss/eviction counters are fleet-wide —
  the whole fleet shares one gallery, so a frame embedded for a query on
  shard 0 is cache-hot for a query on shard 3.

Both share the base class's retention bookkeeping, which mirrors
``FrameStore``: a per-camera monotonic key deque gives O(1) amortized
retention-horizon eviction on ``put``; an out-of-order ``put`` stays correct
(``get`` re-checks the horizon) but its eviction may be deferred until the
deque head catches up to it.  ``FrameStore`` additionally calls ``drop`` for
every frame key it evicts, so embeddings never outlive their frames.
"""
from __future__ import annotations

import collections
from typing import Any

import numpy as np

from repro.runtime.transport import LocalFetchHandle, PeerDeadError


def pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1) — the shared padding rule for jit
    shapes and device-resident gallery blocks."""
    return 1 << max(n - 1, 0).bit_length()


def l2_normalize(a: np.ndarray, eps: float = 1e-9) -> np.ndarray:
    """Row-unit-normalize 1-D or 2-D embeddings (zero rows stay zero).

    The embedding plane's ONE normalization rule: the engines call this at
    ingest/update time so the hot round bodies never run host-numpy
    reductions per round (lint rule REX001)."""
    a = np.asarray(a, np.float32)
    if a.ndim == 1:
        return a / max(float(np.linalg.norm(a)), eps)
    return a / np.maximum(np.linalg.norm(a, axis=-1, keepdims=True), eps)


def _cam_hash(cam: int) -> int:
    """Stable camera hash (Knuth multiplicative) for owner-shard choice —
    spreads consecutive camera ids instead of striping them."""
    return ((cam + 1) * 2654435761) & 0xFFFFFFFF


class GalleryStore:
    """The embedding-plane contract both engines program to.

    ``put(cam, t, emb) -> bool`` caches one (camera, frame) embedding block
    (False = rejected: already behind the retention horizon), ``get`` returns
    the cached block or None (miss / evicted), ``drop`` removes one key (the
    frame-eviction driven path).  Subclasses implement the storage backend
    (``_store`` / ``_fetch`` / ``_drop``); retention bookkeeping and the
    hit/miss/eviction/put/rejected counters live here so every backend
    behaves identically.
    """

    kind = "base"

    def __init__(self, n_cams: int, retention: int):
        self.n_cams = n_cams
        self.retention = retention
        self._keys: list[collections.deque] = [collections.deque()
                                               for _ in range(n_cams)]
        self._latest = np.full(n_cams, -1, np.int64)
        self.hits = 0        # get() served from the store
        self.misses = 0      # get() found nothing (uncached or evicted)
        self.evictions = 0   # cached blocks dropped (horizon or frame-evict)
        self.puts = 0        # blocks accepted
        self.rejected = 0    # puts refused (behind the retention horizon)
        self.prefetch_hits = 0    # blocks served from the prefetch buffer
        self.prefetch_wasted = 0  # prefetched blocks discarded (misspeculation)

    # -- retention bookkeeping (FrameStore-identical) ----------------------
    def _horizon(self, cam: int) -> int:
        return int(self._latest[cam]) - self.retention

    def _evict_horizon(self, cam: int) -> None:
        horizon = self._horizon(cam)
        keys = self._keys[cam]
        while keys and keys[0] < horizon:
            key = keys.popleft()
            if self._drop(cam, key):
                self.evictions += 1

    # -- the contract ------------------------------------------------------
    def put(self, cam: int, t: int, emb: Any) -> bool:
        """Cache one embedding block; False when t is already behind the
        retention horizon (the write would be dead on arrival)."""
        if t > self._latest[cam]:
            self._latest[cam] = t
        if t < self._horizon(cam):
            self.rejected += 1
            return False
        if not self._has(cam, t):
            self._keys[cam].append(t)
        self._store(cam, t, emb)
        self.puts += 1
        self._evict_horizon(cam)
        return True

    def get(self, cam: int, t: int) -> Any:
        """Cached block for (cam, t), or None.  Re-checks the horizon so an
        out-of-order put whose eviction is deferred never serves stale data."""
        if t < self._horizon(cam):
            self.misses += 1
            return None
        emb = self._fetch(cam, t)
        if emb is None:
            self.misses += 1
        else:
            self.hits += 1
        return emb

    def cached(self, cam: int, t: int) -> bool:
        """Whether a retained block for (cam, t) is resident right now —
        the prefetch plane's validity check, no counters tick."""
        return t >= self._horizon(cam) and self._has(cam, t)

    def fetch_async(self, cam: int, t: int):
        """Issue an async fetch for a CACHED (cam, t) block: a handle for
        ``wait_fetch``, or None when the block is uncached / behind the
        horizon.  No hit/miss counters tick at issue time — the consumer
        accounts at consume time (``PrefetchPipeline``), so speculation
        never skews the cache statistics."""
        if t < self._horizon(cam) or not self._has(cam, t):
            return None
        return self._fetch_async(cam, t)

    def wait_fetch(self, handle) -> Any:
        """Deliver an async fetch.  May return None (the block vanished
        between issue and wait) or raise ``PeerDeadError`` (remote owner
        lost mid-fetch); the caller falls back to the blocking path."""
        if isinstance(handle, LocalFetchHandle):
            if handle.t < self._horizon(handle.cam):
                return None
            return self._fetch(handle.cam, handle.t)
        raise TypeError(f"unknown fetch handle {handle!r}")

    def drop(self, cam: int, t: int) -> bool:
        """Remove one key (frame-eviction driven: ``FrameStore`` calls this
        for every frame it evicts so embeddings never outlive frames).  The
        deque entry stays; popping it later is a no-op."""
        removed = self._drop(cam, t)
        if removed:
            self.evictions += 1
        return removed

    # -- backend hooks -----------------------------------------------------
    def _store(self, cam: int, t: int, emb: Any) -> None:
        raise NotImplementedError

    def _fetch(self, cam: int, t: int) -> Any:
        raise NotImplementedError

    def _drop(self, cam: int, t: int) -> bool:
        raise NotImplementedError

    def _has(self, cam: int, t: int) -> bool:
        raise NotImplementedError

    def _fetch_async(self, cam: int, t: int) -> Any:
        """Backend async fetch for a known-resident key.  The base path is
        the degenerate immediate handle (re-reads the store at wait time);
        a transport-backed store returns a real in-flight handle."""
        return LocalFetchHandle(cam, t)

    # -- accounting --------------------------------------------------------
    def cached_embeddings(self) -> int:
        raise NotImplementedError

    def memory_bytes(self) -> int:
        raise NotImplementedError

    def counters(self) -> dict:
        # transport-era keys are zeros here; a transport-backed store
        # overrides them with the live fetch-plane stats
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, puts=self.puts,
                    rejected=self.rejected, cached=self.cached_embeddings(),
                    bytes=self.memory_bytes(),
                    prefetch_hits=self.prefetch_hits,
                    prefetch_wasted=self.prefetch_wasted,
                    remote_fetches=0, retries=0, timeouts=0)


class LocalGalleryStore(GalleryStore):
    """Host-resident per-camera dicts — today's per-engine semantics."""

    kind = "local"

    def __init__(self, n_cams: int, retention: int):
        super().__init__(n_cams, retention)
        self._emb: list[dict[int, Any]] = [dict() for _ in range(n_cams)]

    def _store(self, cam, t, emb):
        self._emb[cam][t] = emb

    def _fetch(self, cam, t):
        return self._emb[cam].get(t)

    def _drop(self, cam, t):
        return self._emb[cam].pop(t, None) is not None

    def _has(self, cam, t):
        return t in self._emb[cam]

    def cached_embeddings(self):
        return sum(len(e) for e in self._emb)

    def memory_bytes(self):
        return sum(getattr(e, "nbytes", 0)
                   for d in self._emb for e in d.values())


class ShardedGalleryStore(GalleryStore):
    """One fleet-wide gallery: camera-hash owner shards over the data axis.

    Every camera maps to one owner worker (``_cam_hash(cam) % live``) and
    that camera's blocks are ``jax.device_put`` onto the owner's device,
    rows padded to a power of two (bounded device buffer shapes — the same
    rule the engines use for round galleries).  ``rehome`` migrates a lost
    worker's cameras (and their resident blocks) onto the survivors, the
    gallery-plane counterpart of the fleet's orphan-query re-scatter;
    surviving owners keep their cameras, so only the lost shard moves.

    Blocks must be numpy arrays (the engines' (n, D) float32 embedding
    batches); values round-trip the device bit-exactly, which is what keeps
    the sharded-gallery fleet trace-identical to the single engine.

    With a ``transport`` (``runtime.transport``), every fetch of an
    owner-resident block goes through the fetch plane addressed to the
    block's owner peer — in-proc that is a zero-copy read, fake-RPC it
    pays injected latency and may retry/time out.  A ``PeerDeadError``
    during a blocking fetch re-resolves ownership: if the dead-peer signal
    re-homed the camera (the fleet's ``on_dead`` wiring), the fetch retries
    against the block's new owner; otherwise it surfaces.
    """

    kind = "sharded"

    def __init__(self, n_cams: int, retention: int, workers: list[str],
                 device_of: dict[str, Any], transport: Any = None):
        super().__init__(n_cams, retention)
        if not workers:
            raise ValueError("sharded gallery needs at least one worker")
        missing = [w for w in workers if w not in device_of]
        if missing:
            raise ValueError(f"workers {missing} have no device mapping")
        self._device_of = dict(device_of)
        self._owner = {cam: workers[_cam_hash(cam) % len(workers)]
                       for cam in range(n_cams)}
        # (cam, t) -> (device-resident padded block, valid row count)
        self._blocks: dict[tuple[int, int], tuple[Any, int]] = {}
        self.rehomed_blocks = 0
        self.transport = transport

    def owner_of(self, cam: int) -> str:
        return self._owner[cam]

    def _store(self, cam, t, emb):
        import jax

        emb = np.asarray(emb)
        n = emb.shape[0]
        rows = pow2(n)
        if rows > n:
            emb = np.concatenate(
                [emb, np.zeros((rows - n,) + emb.shape[1:], emb.dtype)])
        self._blocks[(cam, t)] = (
            jax.device_put(emb, self._device_of[self._owner[cam]]), n)

    @staticmethod
    def _read_block(blk):
        arr, n = blk
        return np.asarray(arr)[:n]

    def _fetch(self, cam, t):
        while True:
            blk = self._blocks.get((cam, t))
            if blk is None:
                return None
            if self.transport is None:
                return self._read_block(blk)
            owner = self._owner[cam]
            try:
                return self.transport.fetch(owner, (cam, t),
                                            lambda b=blk: self._read_block(b))
            except PeerDeadError:
                if self._owner[cam] == owner:
                    raise          # nobody re-homed the camera: surface it
                # the dead-peer signal re-homed it mid-fetch — retry against
                # the new owner (the block moved with the camera)

    def _fetch_async(self, cam, t):
        if self.transport is None:
            return super()._fetch_async(cam, t)
        blk = self._blocks[(cam, t)]
        return self.transport.fetch_async(self._owner[cam], (cam, t),
                                          lambda: self._read_block(blk))

    def wait_fetch(self, handle):
        if isinstance(handle, LocalFetchHandle):
            return super().wait_fetch(handle)
        return self.transport.wait(handle)

    def _drop(self, cam, t):
        return self._blocks.pop((cam, t), None) is not None

    def _has(self, cam, t):
        return (cam, t) in self._blocks

    def rehome(self, lost: str, survivors: list[str]) -> int:
        """Re-home the lost worker's cameras onto the survivors (camera-hash
        over the surviving list) and migrate their resident blocks.  Returns
        the number of blocks moved."""
        import jax

        if not survivors:
            raise RuntimeError("cannot re-home the gallery: no survivors")
        remap = {cam: survivors[_cam_hash(cam) % len(survivors)]
                 for cam, w in self._owner.items() if w == lost}
        self._owner.update(remap)
        moved = 0
        for key, (arr, n) in list(self._blocks.items()):
            if key[0] in remap:
                self._blocks[key] = (
                    jax.device_put(np.asarray(arr),
                                   self._device_of[remap[key[0]]]), n)
                moved += 1
        self.rehomed_blocks += moved
        return moved

    def cached_embeddings(self):
        return len(self._blocks)

    def memory_bytes(self):
        return sum(arr.nbytes for arr, _ in self._blocks.values())

    def counters(self):
        c = dict(super().counters(), rehomed_blocks=self.rehomed_blocks)
        if self.transport is not None:
            c.update(self.transport.counters())
        return c

    def per_worker_report(self) -> dict[str, dict]:
        """Owner-resident cache memory, per worker: cameras owned, resident
        blocks/rows/bytes, plus the fetch plane's per-peer traffic when a
        transport is attached.  Lost workers report zeros after ``rehome``."""
        rep = {w: dict(cameras=0, blocks=0, rows=0, bytes=0,
                       remote_fetches=0, retries=0, timeouts=0)
               for w in self._device_of}
        for w in self._owner.values():
            rep[w]["cameras"] += 1
        for (cam, _t), (arr, n) in self._blocks.items():
            r = rep[self._owner[cam]]
            r["blocks"] += 1
            r["rows"] += n
            r["bytes"] += arr.nbytes
        if self.transport is not None:
            for w, st in self.transport.peer_counters().items():
                if w in rep:
                    rep[w]["remote_fetches"] = st["fetches"]
                    rep[w]["retries"] = st["retries"]
                    rep[w]["timeouts"] = st["timeouts"]
        return rep


def assemble_round_gallery(batch_keys: list[tuple[int, int]],
                           key_emb: dict[tuple[int, int], np.ndarray],
                           min_rows: int = 1):
    """One round's deduplicated gallery, engine-ready: concatenate the
    per-key embedding blocks IN ``batch_keys`` ORDER (the engines pass
    camera-major sorted keys, which is what keeps the kernel's flat-argmin
    tie-breaking bit-identical to the tracker), tag every row with its
    (camera, frame), and pad rows to a power of two so jit shapes stay
    bounded — padded rows carry cam/frame -1 and rank to (NEG_INF, -1)
    inside the kernels.  ``min_rows`` lets the engines hold the row count at
    its high-water mark (growth-only padding, so the jitted rank signature
    stays frozen when a round's gallery shrinks — padded rows can never win
    a tie, the kernel's flat argmin always resolves equal scores to the
    lowest real column).  Returns (gallery (Gp, D), gal_cam (Gp,),
    gal_frame (Gp,))."""
    counts = [len(key_emb[k]) for k in batch_keys]
    gal = np.concatenate([key_emb[k] for k in batch_keys]).astype(np.float32)
    gal_cam = np.repeat([k[0] for k in batch_keys], counts).astype(np.int32)
    gal_frame = np.repeat([k[1] for k in batch_keys], counts).astype(np.int32)
    G = gal.shape[0]
    Gp = max(pow2(G), pow2(min_rows))
    if Gp > G:
        gal = np.concatenate(
            [gal, np.zeros((Gp - G, gal.shape[1]), np.float32)])
        gal_cam = np.concatenate([gal_cam, np.full(Gp - G, -1, np.int32)])
        gal_frame = np.concatenate([gal_frame, np.full(Gp - G, -1, np.int32)])
    return gal, gal_cam, gal_frame
