from repro.runtime.engine import ServingEngine, EngineConfig, QueryState  # noqa: F401
from repro.runtime.fleet import ShardedServingEngine  # noqa: F401
from repro.runtime.gallery import (GalleryStore, LocalGalleryStore,  # noqa: F401
                                   ShardedGalleryStore)
from repro.runtime.stream_store import FrameStore  # noqa: F401
from repro.runtime.cluster import HeartbeatMonitor, ElasticMesh  # noqa: F401
from repro.runtime.recal import (RecalibrationController,  # noqa: F401
                                 RecalibrationPolicy, match_log_source,
                                 visits_window_source)
