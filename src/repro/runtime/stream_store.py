"""Per-camera ring buffer of recent frames — the replay substrate (paper §5.3).

The paper: "Implicit to replay search is also the ability to store videos in
the past.  However, this only needs to be for the last few minutes."  The
store keeps a bounded window per camera; replay reads are range queries into
it, and reads past the retention window raise (that replay would have to fall
back to cold storage — surfaced to the caller as a miss).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


class FrameStore:
    def __init__(self, n_cams: int, retention: int):
        self.n_cams = n_cams
        self.retention = retention
        self._buf: list[dict[int, Any]] = [dict() for _ in range(n_cams)]
        self._latest = np.full(n_cams, -1, np.int64)

    def append(self, cam: int, t: int, frame: Any) -> None:
        buf = self._buf[cam]
        buf[t] = frame
        self._latest[cam] = max(self._latest[cam], t)
        # evict
        horizon = self._latest[cam] - self.retention
        for key in [k for k in buf if k < horizon]:
            del buf[key]

    def get(self, cam: int, t: int) -> Any:
        horizon = self._latest[cam] - self.retention
        if t < horizon:
            raise KeyError(f"frame ({cam}, {t}) evicted (retention {self.retention})")
        return self._buf[cam].get(t)

    def range(self, cam: int, t0: int, t1: int) -> list[tuple[int, Any]]:
        """Frames in [t0, t1] still retained (replay read)."""
        horizon = self._latest[cam] - self.retention
        return [(t, self._buf[cam][t]) for t in range(max(t0, horizon), t1 + 1)
                if t in self._buf[cam]]

    def memory_frames(self) -> int:
        return sum(len(b) for b in self._buf)
