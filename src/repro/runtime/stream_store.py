"""Per-camera ring buffer of recent frames — the replay substrate (paper §5.3).

The paper: "Implicit to replay search is also the ability to store videos in
the past.  However, this only needs to be for the last few minutes."  The
store keeps a bounded window per camera; replay reads are range queries into
it, and reads past the retention window raise (that replay would have to fall
back to cold storage — surfaced to the caller as a miss).

The *embedding plane* is delegated: alongside the raw frames the store
fronts a ``runtime.gallery.GalleryStore`` (injected; a per-engine
``LocalGalleryStore`` by default, the fleet injects the shared
``ShardedGalleryStore``).  The serving engine writes each (camera, frame)
batch's backbone embeddings back via ``put_emb`` after the first (live)
pass, so a phase-2 replay re-read of a still-retained frame skips
re-embedding entirely — the single largest avoidable cost in the replay
path.  ``put_emb`` returns whether the write was actually cached: a frame
never appended (or already evicted) is refused, not silently dropped.
Embeddings are evicted together with their frames (``gallery.drop`` on
every frame eviction).

Eviction is O(1) amortized: appended keys go on a per-camera monotonic
deque, and each append pops only the keys that just crossed the retention
horizon (the previous implementation rescanned every retained key per
append — O(retention) per frame).  Appends are expected in nondecreasing
``t`` order per camera (the engine's wall clock guarantees this); an
out-of-order append stays correct — ``get`` re-checks the horizon — but its
eviction may be deferred until the deque head reaches it.
"""
from __future__ import annotations

import collections
from typing import Any

import numpy as np

from repro.runtime.gallery import GalleryStore, LocalGalleryStore


class FrameStore:
    def __init__(self, n_cams: int, retention: int,
                 gallery: GalleryStore | None = None):
        self.n_cams = n_cams
        self.retention = retention
        self.gallery = gallery if gallery is not None \
            else LocalGalleryStore(n_cams, retention)
        self._buf: list[dict[int, Any]] = [dict() for _ in range(n_cams)]
        # per-detection flat tile ids riding alongside each frame (the
        # sub-frame admission plane's labels) — evicted in lockstep
        self._tiles: list[dict[int, Any]] = [dict() for _ in range(n_cams)]
        self._keys: list[collections.deque] = [collections.deque()
                                               for _ in range(n_cams)]
        self._latest = np.full(n_cams, -1, np.int64)

    def _horizon(self, cam: int) -> int:
        return int(self._latest[cam]) - self.retention

    def _evict(self, cam: int) -> None:
        horizon = self._horizon(cam)
        keys, buf, tiles = self._keys[cam], self._buf[cam], self._tiles[cam]
        while keys and keys[0] < horizon:
            key = keys.popleft()
            buf.pop(key, None)
            tiles.pop(key, None)
            self.gallery.drop(cam, key)   # embeddings never outlive frames

    def append(self, cam: int, t: int, frame: Any, tile: Any = None) -> None:
        if t not in self._buf[cam]:
            self._keys[cam].append(t)
        self._buf[cam][t] = frame
        if tile is not None:
            self._tiles[cam][t] = tile
        if t > self._latest[cam]:
            self._latest[cam] = t
        self._evict(cam)

    def get(self, cam: int, t: int) -> Any:
        if t < self._horizon(cam):
            raise KeyError(f"frame ({cam}, {t}) evicted (retention {self.retention})")
        return self._buf[cam].get(t)

    def get_tile(self, cam: int, t: int) -> Any:
        """Per-detection flat tile ids for a retained (cam, t) frame, or
        None when the frame carried no tile labels (tile-mode ingest makes
        labels mandatory, so a None here past ingest is a bookkeeping bug
        the engine surfaces as a RuntimeError — unlabeled gallery rows
        would carry cell -1 and silently match nothing)."""
        if t < self._horizon(cam):
            return None
        return self._tiles[cam].get(t)

    def range(self, cam: int, t0: int, t1: int) -> list[tuple[int, Any]]:
        """Frames in [t0, t1] still retained (replay read)."""
        horizon = self._horizon(cam)
        return [(t, self._buf[cam][t]) for t in range(max(t0, horizon), t1 + 1)
                if t in self._buf[cam]]

    # -- embedding plane (delegated to the gallery store) ------------------
    def put_emb(self, cam: int, t: int, emb: Any) -> bool:
        """Cache the backbone embeddings for a retained (cam, t) frame.
        Returns False (write refused, NOT silently dropped) when the frame
        was never appended or is already behind the retention horizon."""
        if t < self._horizon(cam) or t not in self._buf[cam]:
            self.gallery.rejected += 1   # refusals stay visible fleet-wide
            return False
        return self.gallery.put(cam, t, emb)

    def emb_cached(self, cam: int, t: int) -> bool:
        """Whether a retained embedding block for (cam, t) is resident —
        the prefetch plane's issue/consume validity check (no counters)."""
        return t >= self._horizon(cam) and self.gallery.cached(cam, t)

    def fetch_emb_async(self, cam: int, t: int):
        """Issue an async fetch for a cached (cam, t) embedding block: a
        handle for ``wait_emb``, or None when uncached / behind the frame
        horizon.  Counter-neutral at issue time — the prefetch consumer
        accounts hits and misspeculation exactly."""
        if t < self._horizon(cam):
            return None
        return self.gallery.fetch_async(cam, t)

    def wait_emb(self, handle) -> Any:
        return self.gallery.wait_fetch(handle)

    def get_emb(self, cam: int, t: int) -> Any:
        """Cached embeddings for (cam, t), or None (uncached / evicted).
        The frame horizon is re-checked here too: an out-of-order append
        whose eviction is deferred never serves a stale embedding."""
        if t < self._horizon(cam):
            self.gallery.misses += 1     # a lookup that found nothing
            return None
        return self.gallery.get(cam, t)

    def memory_frames(self) -> int:
        return sum(len(b) for b in self._buf)

    def cached_embeddings(self) -> int:
        return self.gallery.cached_embeddings()
