"""Drift-aware recalibration: the online re-profiling loop (paper §6).

The paper names model drift as the key deployment risk: the offline-profiled
spatio-temporal model M goes stale as traffic patterns shift, and ReXCam's
answer is to watch the replay-rescue rate and re-profile.  The serving plane
already computes the signal — the engine attributes every phase-2 rescue to
its (anchor camera, match camera) pair in a live ``rescue_pairs`` (C, C)
matrix, and ``profiler.drift_score`` normalizes it by the profile's own
transition counts.  This module closes the loop:

  ``RecalibrationController``  polls the score every ``poll_every`` ticks,
      and when it trips the trigger — score above ``drift_threshold`` AND at
      least ``min_rescues`` observed (small-sample guard) AND ``cooldown``
      ticks since the last swap (hysteresis: a borderline score oscillating
      around the threshold must not thrash re-profiles) — re-profiles a
      fresh M from a sliding ``window`` of recent trajectories and hot-swaps
      it into the engine via ``engine.swap_model``.

  The swap is epoch-versioned and atomic between rounds: in-flight queries
  keep their anchors/cursors/phases and simply admit under the new M from
  the next round on.  On the sharded fleet the same controller drives
  ``ShardedServingEngine.swap_model``, which re-replicates M onto every
  shard of the mesh — single-controller, so "atomically on every shard"
  falls out of swapping strictly between ticks.  Trace records carry the
  model epoch, so the fleet-vs-single differential harness pins the swap to
  the same round on both planes.

Trajectory sources — re-profiling needs a visit table for the recent
window, and two are natural:

  ``visits_window_source(visits)``  the deployment recipe: re-run the MTMC
      profiling pass over the last ``window`` steps of video (here: slice
      the simulator's ground-truth visit table).  What ``drift_sweep`` and
      ``launch/serve.py --recalibrate`` use.

  ``match_log_source(engine)``  fully self-contained: rebuild trajectories
      from the engine's OWN confirmed sightings (submit anchors + matches,
      entity = query id).  Sparser — it only sees tracked identities — but
      it is exactly the §6 story: the relaxed replay phase is what discovers
      transitions the stale model prunes, so the rescues that trip the
      trigger also teach the new model the drifted pairs.  The default when
      no source is given.

After a swap the rescue matrix is reset (``reset_rescues``): the old
rescues were evidence against the OLD model, and carrying them over would
re-trigger immediately against the new one — the second half of the
hysteresis besides the cooldown.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.profiler import build_model, drift_score, merge_reprofiled_rows
from repro.core.simulate import Visits

# (ent, cam, t_in, t_out) arrays for a time window — what build_model eats
VisitSource = Callable[[int, int], tuple]


@dataclasses.dataclass(frozen=True)
class RecalibrationPolicy:
    """Trigger knobs for the §6 re-profiling loop.  All times are engine
    ticks (= simulation steps)."""

    # Trip when drift_score.max() reaches this.  Scale intuition: one rescue
    # on a pair the profile never saw scores 1/smoothing (~0.33); k rescues
    # on a pair with n historical transitions score k/(n+smoothing) — dense
    # profiles keep scores small, so 0.1 means "a sustained spike on a pair
    # the profile considered cold", not "10% of traffic moved".
    drift_threshold: float = 0.1
    min_rescues: int = 16          # total rescues before the score is trusted
    cooldown: int = 240            # min ticks between swaps (hysteresis)
    poll_every: int = 20           # score polling cadence
    window: int = 1200             # sliding re-profile window (recent steps)
    smoothing: float = 3.0         # drift_score additive smoothing
    reset_rescues: bool = True     # zero the rescue matrix after a swap
    # Row-targeted re-profiling (the 130-camera regime): instead of a full
    # (C, C, NB) rebuild, re-profile only the source-camera rows whose
    # per-row drift score reaches ``row_threshold`` (None: reuse
    # ``drift_threshold``) and merge them into the incumbent model
    # (``profiler.merge_reprofiled_rows`` — untouched rows carry bit-exact).
    targeted: bool = False
    row_threshold: float | None = None


def visits_window_source(visits: Visits) -> VisitSource:
    """Adapt a ground-truth ``Visits`` table into a sliding-window source:
    ``source(lo, hi)`` returns the visits active inside [lo, hi) — the
    deployment's "re-run the MTMC profiling tracker on the recent video"
    step, which the simulators stand in for."""
    ent = np.asarray(visits.ent)
    cam = np.asarray(visits.cam)
    t_in = np.asarray(visits.t_in)
    t_out = np.asarray(visits.t_out)

    def source(lo: int, hi: int):
        keep = (t_out >= lo) & (t_in < hi)
        return ent[keep], cam[keep], t_in[keep], t_out[keep]

    return source


def match_log_source(engine) -> VisitSource:
    """Rebuild trajectories from the engine's own confirmed sightings
    (``engine.sightings``: submit anchors + every match, entity = qid).
    Each sighting becomes a zero-dwell visit, so consecutive sightings of
    one query yield exactly the (c_s -> c_d, dt) transitions the profiler
    histograms."""

    def source(lo: int, hi: int):
        rows = [(q, c, f) for (q, c, f) in engine.sightings if lo <= f < hi]
        if not rows:
            z = np.zeros(0, np.int64)
            return z, z, z, z
        ent, cam, f = map(np.asarray, zip(*rows))
        return ent, cam, f, f

    return source


class RecalibrationController:
    """Watches one engine's live drift signal and hot-swaps its model.

    Attach via ``repro.api.serve(recalibrate=...)`` (the engine then calls
    ``on_tick`` after every tick) or drive ``on_tick``/``maybe_recalibrate``
    yourself.  ``clock`` defaults to the engine's wall tick ``engine.t``;
    tests inject a fake clock to pin the hysteresis."""

    def __init__(self, engine, visit_source: VisitSource | None = None,
                 policy: RecalibrationPolicy = RecalibrationPolicy(),
                 clock: Callable[[], int] | None = None):
        self.engine = engine
        self.visit_source = visit_source if visit_source is not None \
            else match_log_source(engine)
        self.policy = policy
        self.clock = clock if clock is not None else (lambda: engine.t)
        self.events: list[dict] = []   # one dict per completed swap (rare)
        # recent score history — bounded, a long-running engine polls forever
        self.polls: collections.deque[dict] = collections.deque(maxlen=512)
        self._last_poll: int | None = None
        self._last_swap: int | None = None
        # profiler call accounting — what the soak's "targeted re-computes
        # only the drifted rows" assertion reads: rows actually re-profiled
        # (a full rebuild books all C), swap counts per mode, and the
        # cumulative wall spent inside the profiling step itself
        self.rows_reprofiled = 0
        self.full_rebuilds = 0
        self.targeted_swaps = 0
        self.profile_wall = 0.0

    # -- the drift signal --------------------------------------------------
    def score(self) -> np.ndarray:
        """(C, C) drift score of the engine's live rescue matrix against its
        CURRENT model (normalized rescue spikes, see profiler.drift_score)."""
        return drift_score(self.engine.model, self.engine.rescue_pairs,
                           self.policy.smoothing)

    # -- the trigger -------------------------------------------------------
    def on_tick(self) -> dict | None:
        """Per-tick hook: polls every ``poll_every`` ticks; returns the swap
        event when a recalibration fired, else None."""
        t = int(self.clock())
        if self._last_poll is not None and \
                t - self._last_poll < self.policy.poll_every:
            return None
        self._last_poll = t
        return self.maybe_recalibrate(t)

    def maybe_recalibrate(self, t: int | None = None) -> dict | None:
        """One trigger evaluation (hysteresis included) at time ``t``."""
        p = self.policy
        t = int(self.clock()) if t is None else t
        rescues = int(np.asarray(self.engine.rescue_pairs).sum())
        score_mat = self.score()
        score = float(score_mat.max())
        self.polls.append(dict(t=t, score=score, rescues=rescues))
        if rescues < p.min_rescues:            # small-sample guard
            return None
        if score < p.drift_threshold:          # no drift evidence
            return None
        if self._last_swap is not None and t - self._last_swap < p.cooldown:
            return None                        # cooling down: no thrash
        return self._recalibrate(t, score, rescues, score_mat)

    # -- the re-profile + hot-swap ----------------------------------------
    def _recalibrate(self, t: int, score: float, rescues: int,
                     score_mat: np.ndarray | None = None) -> dict | None:
        p = self.policy
        lo, hi = max(t - p.window, 0), t
        ent, cam, t_in, t_out = self.visit_source(lo, hi)
        if len(ent) == 0:
            return None                        # nothing to profile from
        old = self.engine.model
        if p.targeted:
            # Row-targeted path: re-profile only the source-camera rows whose
            # drift score implicates them; untouched rows carry bit-exact
            # (ROW_LOCAL_FIELDS contract — see core.correlation).
            if score_mat is None:
                score_mat = self.score()
            thr = p.drift_threshold if p.row_threshold is None \
                else p.row_threshold
            row_max = np.asarray(score_mat).max(axis=1)
            rows = np.flatnonzero(row_max >= thr)
            if len(rows) == 0:                 # trigger fired: take the worst
                rows = np.array([int(row_max.argmax())], np.int64)
            t_prof = time.perf_counter()
            fresh = merge_reprofiled_rows(old, ent, cam, t_in, t_out, rows)
            self.profile_wall += time.perf_counter() - t_prof
            self.targeted_swaps += 1
            mode = "targeted"
        else:
            t_prof = time.perf_counter()
            fresh = build_model(ent, cam, t_in, t_out, self.engine.C,
                                n_bins=old.n_bins, bin_width=old.bin_width)
            self.profile_wall += time.perf_counter() - t_prof
            self.full_rebuilds += 1
            rows = np.arange(self.engine.C, dtype=np.int64)
            mode = "full"
        self.rows_reprofiled += int(len(rows))
        epoch = self.engine.swap_model(fresh)
        if p.reset_rescues:
            self.engine.rescue_pairs[:] = 0
        self._last_swap = t
        event = dict(t=t, epoch=epoch, score=score, rescues=rescues,
                     window=(lo, hi), visits=int(len(ent)), mode=mode,
                     rows=int(len(rows)),
                     row_ids=[int(r) for r in rows] if mode == "targeted"
                     else None)
        self.events.append(event)
        return event
