"""Re-id feature oracle for the simulators (DESIGN.md §7).

Entity appearance embeddings are drawn from a clustered distribution
(lookalike groups — people in similar clothing) and every *visit* of an
entity gets a fixed per-visit perturbation (per-camera lighting/viewpoint).
Distances between these features drive the same ranking step the paper's
ResNet-50 re-id model performs (Fig. 2); cluster tightness + noise are
calibrated so the all-camera baseline lands at the paper's ~51% precision /
~81% recall operating point (§8.2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.simulate import Visits


@dataclasses.dataclass(frozen=True)
class FeatureParams:
    """Calibrated (scripts/calibrate.py) so the Duke all-camera baseline lands
    at the paper's ~0.51 precision / ~0.81 recall operating point (Fig. 11)."""
    dim: int = 64
    n_clusters: int = 150          # lookalike groups
    cluster_delta: float = 0.55    # individual separation within a cluster
    noise_sigma: float = 0.45      # per-visit appearance noise
    seed: int = 0


def make_features(visits: Visits, n_entities: int, p: FeatureParams):
    """Returns (feats (V, D) float32 L2-normalized, entity_emb (E, D))."""
    rng = np.random.default_rng(p.seed)

    def unit(x):
        return x / np.linalg.norm(x, axis=-1, keepdims=True)

    centers = unit(rng.normal(size=(p.n_clusters, p.dim)))
    assign = rng.integers(0, p.n_clusters, n_entities)
    indiv = unit(rng.normal(size=(n_entities, p.dim)))
    emb = unit(centers[assign] + p.cluster_delta * indiv)

    noise = unit(rng.normal(size=(len(visits), p.dim)))
    feats = unit(emb[visits.ent] + p.noise_sigma * noise)
    return feats.astype(np.float32), emb.astype(np.float32)
