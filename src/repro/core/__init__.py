"""The paper's primary contribution: spatio-temporal correlation filtering for
cross-camera video analytics (ReXCam §5-§6), plus the calibrated trajectory
simulators used to validate the paper's claims (DESIGN.md §7).
"""
from repro.core.correlation import SpatioTemporalModel  # noqa: F401
from repro.core.policy import (  # noqa: F401
    PhaseState, PhaseWindows, SearchPolicy, admit, advance, phase_windows,
)
from repro.core.profiler import (  # noqa: F401
    build_model, merge_reprofiled_rows, transitions_from_visits,
)
from repro.core.simulate import (  # noqa: F401
    CameraNetwork, Visits, simulate_network, duke_like_network,
    anoncampus_like_network, porto_like_network, clustered_city_network,
    build_gallery, permute_network, concat_visits,
)
from repro.core.tracker import TrackerParams, track_queries, TrackResult  # noqa: F401
from repro.core.detect import DetectorParams, identity_detection  # noqa: F401
