"""The admission control plane: one `SearchPolicy`, one `admit`, one `advance`.

This module is the single home of ReXCam's spatio-temporal admission
semantics (paper §5.1-§5.3, Algorithm 1).  Every consumer — the batched
offline tracker (``repro.core.tracker``), the live serving engine
(``repro.runtime.engine``), benchmarks and examples via ``repro.api`` —
drives the same three primitives:

  ``SearchPolicy``   frozen, hashable search configuration (scheme,
                     thresholds, relax/replay settings).  Static under jit.
  ``PhaseState``     batched (Q,) pytree of per-query search state: the
                     last-seen anchor (c_q, f_q), the content cursor f_curr,
                     the live frontier, the Alg.-1 phase, and done flags.
  ``admit``          pure, vectorized (Q, C) admission-mask construction —
                     the ONLY place a correlation threshold is compared.
  ``advance``        pure phase-machine step: match resets, window
                     exhaustion, the phase-2 rewind to f_q + 1, the optional
                     phase-3 exhaustive pass, and exit-threshold termination.

Phase semantics (§5.2-5.3, Alg. 1 line 21): phase 1 searches the normal
spatio-temporal windows; when those are *exhausted* the tracker rewinds to
f_q + 1 and replays with thresholds relaxed x ``relax_factor`` (phase 2).
When the relaxed windows are exhausted too, the model's prediction is that
the query has exited; ``exhaustive_final=True`` additionally runs the
paper's literal all-camera terminal sweep (phase 3) — off by default since
the paper's reported ~3 s delays show it cannot run per query (DESIGN.md
§7).  ``exit_t`` is the baseline's "maximum duration" (§3.2) and an upper
bound on every phase.

Replay lag follows §5.3: a cursor behind the live frontier processes
*historical* frames; skip mode (process 1-in-k) and fast-forward mode
(k x throughput) trade cost, accuracy and delay differently.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # avoid a runtime import cycle with correlation.py
    from repro.core.correlation import SpatioTemporalModel


# ---------------------------------------------------------------------------
# The model query interface: thresholds vs the model's raw arrays.
# ``SpatioTemporalModel`` methods delegate here so admission-mask
# construction lives in exactly one module.
# ---------------------------------------------------------------------------

def spatial_mask(model: "SpatioTemporalModel", c_s, s_thresh) -> jnp.ndarray:
    """Destinations spatially correlated with c_s.

    Scalar c_s -> (C,); batched c_s (Q,) with per-query thresholds -> (Q, C).
    """
    th = jnp.asarray(s_thresh)
    if jnp.ndim(c_s) > 0 and th.ndim > 0:
        th = th[:, None]
    return model.S[c_s] >= th


def temporal_mask(model: "SpatioTemporalModel", c_s, elapsed, t_thresh) -> jnp.ndarray:
    """Destinations temporally correlated at ``elapsed`` steps since c_s.

    The fraction already arrived at time t is the CDF *before* t's bin — the
    exclusive form keeps the arrival bin itself searchable even for
    degenerate (zero-variance) travel-time distributions.  Scalar args ->
    (C,); batched (Q,) args -> (Q, C).
    """
    batched = jnp.ndim(c_s) > 0 or jnp.ndim(elapsed) > 0
    c, e = jnp.broadcast_arrays(jnp.atleast_1d(jnp.asarray(c_s)),
                                jnp.atleast_1d(jnp.asarray(elapsed)))
    th = jnp.broadcast_to(jnp.asarray(t_thresh), c.shape)
    b = jnp.clip(e // model.bin_width, 0, model.n_bins - 1)
    arrived = jnp.where((b > 0)[:, None],
                        model.cdf[c, :, jnp.maximum(b - 1, 0)], 0.0)
    started = e[:, None] >= model.f0[c]
    out = started & (arrived <= 1.0 - th[:, None])
    return out if batched else out[0]


def correlated(model: "SpatioTemporalModel", c_s, elapsed, s_thresh, t_thresh) -> jnp.ndarray:
    """M(c_s, ·, elapsed): bool mask over destination cameras."""
    return spatial_mask(model, c_s, s_thresh) & \
        temporal_mask(model, c_s, elapsed, t_thresh)


def window_end(model: "SpatioTemporalModel", s_thresh: float, t_thresh: float) -> jnp.ndarray:
    """(C,) — per source camera, the elapsed time beyond which NO admitted
    destination's temporal window is still open (Alg. 1 line 21's exhaustion
    test, vectorized).  t_thresh=0 never exhausts within the histogram
    range.  +1 bin for the exclusive-CDF convention of ``temporal_mask``."""
    open_bins = ((model.cdf <= 1.0 - t_thresh).sum(-1) + 1) * model.bin_width
    open_bins = jnp.minimum(open_bins, model.n_bins * model.bin_width)  # (C,C)
    admitted = model.S >= s_thresh
    ends = jnp.where(admitted, open_bins, 0)
    return ends.max(axis=1)


def potential_savings(model: "SpatioTemporalModel", s_thresh: float,
                      t_thresh: float, weight_by_traffic: bool = True) -> float:
    """Analytic potential (paper §3.2): ratio of camera-steps searched by a
    correlation-agnostic baseline (all C cameras for the max window) to the
    camera-steps M admits, averaged over source cameras (optionally
    traffic-weighted).  Spatial-only: t_thresh=0.  Temporal-only: s_thresh=0."""
    C = model.n_cams
    sp = np.asarray(model.S) >= s_thresh                # (C, C) searched pairs
    cdf = np.asarray(model.cdf)
    f0 = np.asarray(model.f0)
    NB = cdf.shape[-1]
    b = np.arange(NB)[None, None, :] * model.bin_width  # (1,1,NB) bin start times
    active = (b >= f0[..., None]) & (cdf <= 1.0 - t_thresh)   # (C,C,NB)
    steps = (active.sum(-1) * model.bin_width) * sp     # (C,C) searched steps
    per_src = steps.sum(1).astype(np.float64)           # camera-steps per source
    baseline = C * NB * model.bin_width
    if weight_by_traffic:
        w = np.asarray(model.counts).sum(1).astype(np.float64)
        w = w / max(w.sum(), 1.0)
        filt = float((per_src * w).sum())
    else:
        filt = float(per_src.mean())
    return baseline / max(filt, 1e-9)


# ---------------------------------------------------------------------------
# SearchPolicy — the one search configuration every consumer shares.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SearchPolicy:
    """Algorithm-1 parameters (supersedes the old TrackerParams and the
    overlapping EngineConfig fields).  Frozen and hashable: pass as a static
    argument under jit."""

    scheme: str = "rexcam"          # rexcam | all | geo | spatial_only
    s_thresh: float = 0.05
    t_thresh: float = 0.02
    exit_t: int = 240               # max steps without a match (baseline window)
    match_thresh: float = 0.28      # cosine-distance acceptance
    feat_alpha: float = 0.25        # query-representation EMA rate
    relax_factor: float = 10.0      # replay threshold relaxation (paper: x10)
    replay_speed: float = 1.0       # >1 = parallelism ("ff") mode
    replay_skip: int = 1            # >1 = frame-skip mode
    use_replay: bool = True
    exhaustive_final: bool = False  # paper-literal terminal all-camera pass
    self_window: int = 6            # steps the last-seen camera stays admitted

    @property
    def use_spatial(self) -> bool:
        return self.scheme in ("rexcam", "spatial_only")

    @property
    def use_temporal(self) -> bool:
        return self.scheme == "rexcam" and self.t_thresh > 0.0

    @property
    def replay_rate(self) -> float:
        """Content steps consumed per wall step while replaying."""
        return self.replay_speed * self.replay_skip


# ---------------------------------------------------------------------------
# PhaseState + precomputed exhaustion windows.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PhaseState:
    """Batched (Q,) per-query search state — the Alg.-1 state machine."""

    f_q: jnp.ndarray     # (Q,) int32   frame of the last confirmed sighting
    c_q: jnp.ndarray     # (Q,) int32   camera of the last confirmed sighting
    f_curr: jnp.ndarray  # (Q,) int32   content frame the search cursor is on
    phase: jnp.ndarray   # (Q,) int32   1 = normal, 2 = relaxed replay, >=3 = exhaustive
    live_f: jnp.ndarray  # (Q,) float32 live frontier (content time of "now")
    done: jnp.ndarray    # (Q,) bool    search concluded

    @classmethod
    def init(cls, c_q, f_q) -> "PhaseState":
        """Fresh phase-1 state anchored at the (c_q, f_q) sightings."""
        f_q = jnp.asarray(f_q, jnp.int32)
        c_q = jnp.asarray(c_q, jnp.int32)
        return cls(f_q=f_q, c_q=c_q, f_curr=f_q + 1,
                   phase=jnp.ones_like(f_q),
                   live_f=(f_q + 1).astype(jnp.float32),
                   done=jnp.zeros(f_q.shape, jnp.bool_))

    @property
    def elapsed(self) -> jnp.ndarray:
        return self.f_curr - self.f_q

    @property
    def behind(self) -> jnp.ndarray:
        """Replaying: the cursor is strictly behind the live frontier."""
        return self.f_curr.astype(jnp.float32) < self.live_f - 0.5


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PhaseWindows:
    """Per-source-camera exhaustion horizons for phases 1 and 2."""

    w_end1: jnp.ndarray  # (C,) phase-1 window end
    w_end2: jnp.ndarray  # (C,) relaxed (phase-2) window end


def phase_windows(model: "SpatioTemporalModel", policy: SearchPolicy) -> PhaseWindows:
    t_th = policy.t_thresh if policy.use_temporal else 0.0
    w1 = window_end(model, policy.s_thresh, t_th)
    w2 = window_end(model, policy.s_thresh / policy.relax_factor,
                    t_th / policy.relax_factor)
    clamp = lambda w: jnp.minimum(jnp.maximum(w, policy.self_window), policy.exit_t)  # noqa: E731
    return PhaseWindows(w_end1=clamp(w1), w_end2=clamp(w2))


# ---------------------------------------------------------------------------
# admit — the one admission-mask construction.
# ---------------------------------------------------------------------------

def replay_sampled_out(policy: SearchPolicy, f_q, f_curr, behind):
    """§5.3 skip mode: True where a replaying cursor's content frame is
    sampled out by the 1-in-k gate (its admission mask is all-False by
    construction).  Works batched (jnp arrays, inside ``admit``) and scalar
    (python ints/bools, the engine's host-side short-circuit of sampled-out
    replay rounds) — so the gate lives in exactly one place."""
    if policy.replay_skip <= 1:
        return behind & False          # shape/type-preserving all-False
    return behind & ((f_curr - f_q) % policy.replay_skip != 0)

def admit(model: "SpatioTemporalModel", policy: SearchPolicy, state: PhaseState,
          geo_adj=None) -> jnp.ndarray:
    """(Q, C) bool: which cameras each live query searches at its cursor.

    Pure and jit-compatible (``policy`` static).  Combines the scheme's
    correlation mask, the self-camera follow window, the phase-2 threshold
    relaxation, the phase-3 exhaustive pass, and §5.3 skip-mode sampling of
    historical frames.  Done queries admit nothing.
    """
    Q = state.f_q.shape[0]
    C = model.S.shape[0]
    elapsed = state.elapsed

    # last-seen camera stays admitted briefly (single-camera follow)
    self_mask = jax.nn.one_hot(state.c_q, C, dtype=jnp.bool_) & \
        (elapsed <= policy.self_window)[:, None]

    if policy.scheme == "all":
        mask = jnp.ones((Q, C), bool)
    elif policy.scheme == "geo":
        if geo_adj is None:                 # no proximity data: degrade to all
            geo_adj = jnp.ones((C, C), bool)
        mask = geo_adj[state.c_q] | self_mask
    else:
        relax = jnp.where(state.phase >= 2, 1.0 / policy.relax_factor, 1.0)
        sp = spatial_mask(model, state.c_q, policy.s_thresh * relax) \
            if policy.use_spatial else jnp.ones((Q, C), bool)
        tp = temporal_mask(model, state.c_q, elapsed, policy.t_thresh * relax) \
            if policy.use_temporal else jnp.ones((Q, C), bool)
        mask = (sp & tp) | self_mask
        mask = jnp.where(state.phase[:, None] >= 3, True, mask)  # exhaustive pass

    # lag-aware processing: behind the live frontier -> historical frames,
    # optionally sampled 1-in-k (skip mode)
    process = ~replay_sampled_out(policy, state.f_q, state.f_curr, state.behind)
    return mask & process[:, None] & (~state.done)[:, None]


def tile_follow_mask(tile_q: jnp.ndarray, T: int) -> jnp.ndarray:
    """(Q, T*T) bool: the 3x3 neighborhood of each query's last-matched
    tile on the T x T grid (clipped at frame edges) — the same 1-tile halo
    the profiler dilates its entry-region masks by, covering per-frame
    jitter and slow in-FOV motion.  ``tile_q < 0`` (no match yet: the
    anchor detection carries no tile) admits every tile."""
    cells = jnp.arange(T * T, dtype=jnp.int32)
    cy, cx = cells // T, cells % T
    qy, qx = (tile_q[:, None] // T), (tile_q[:, None] % T)
    near = (jnp.abs(cy[None, :] - qy) <= 1) & (jnp.abs(cx[None, :] - qx) <= 1)
    return near | (tile_q < 0)[:, None]


def tile_admission(model: "SpatioTemporalModel", policy: SearchPolicy,
                   state: PhaseState,
                   tile_q: jnp.ndarray | None = None) -> jnp.ndarray:
    """(Q, C, T*T) bool: which sub-frame tiles of each destination camera a
    query searches, from the profiled entry-region masks
    ``model.tile_admit[c_q]``.

    Recall-preserving relaxations mirror the camera plane's: the relaxed
    replay and exhaustive phases (phase >= 2) admit every tile — a rescue
    pass must not re-apply the spatial prior whose miss it is rescuing —
    and pairs the profiler never observed are already all-True in the
    tensor itself.

    The self camera (the follow window) is where entry-region priors say
    nothing: an entity mid-FOV is wherever it was last seen, not at a
    portal.  With a LEARNED model (``model.tile_learned``) and per-query
    last-matched tiles ``tile_q``, the self column narrows to
    ``tile_follow_mask`` — the last tile +- a 1-tile halo, all tiles until
    the first match.  A synthesized (tile-less) model keeps the whole
    frame, preserving the bit-identity with camera-granular serving the
    tile differential pins."""
    C = model.S.shape[0]
    tiles = model.tile_admit[state.c_q]                  # (Q, C, TT)
    self_cam = jax.nn.one_hot(state.c_q, C, dtype=jnp.bool_)
    if model.tile_learned and tile_q is not None:
        # inside the follow window the self column narrows to the follow
        # mask (a missed novel re-entry is phase 2's to rescue, all tiles);
        # outside it, self admission only comes from observed self-transit
        # correlation, so the learned diagonal (re-entry portals) applies
        follow = tile_follow_mask(tile_q, model.tile_grid)   # (Q, TT)
        diag = model.tile_admit[state.c_q, state.c_q]        # (Q, TT)
        self_col = jnp.where((state.elapsed <= policy.self_window)[:, None],
                             follow, diag)
        tiles = jnp.where(self_cam[:, :, None], self_col[:, None, :], tiles)
    else:
        self_mask = self_cam & (state.elapsed <= policy.self_window)[:, None]
        tiles = tiles | self_mask[:, :, None]
    return tiles | (state.phase >= 2)[:, None, None]


def admit_tiles(model: "SpatioTemporalModel", policy: SearchPolicy,
                state: PhaseState, geo_adj=None, tile_q=None):
    """Tile-granular admission: the (Q, C) camera mask (identical to
    ``admit`` — the tile plane refines, never changes, WHICH cameras are
    searched) plus the fused (Q, C*T*T) per-(camera, tile) admission the
    tile kernel consumes: ``mask_ct[q, c*T*T + t] = mask[q, c] AND
    tile_admission[q, c, t]``.  ``tile_q`` (Q,) int32 is each query's
    last-matched tile (-1 before the first match) — only consulted for a
    learned model's self-camera follow column."""
    mask = admit(model, policy, state, geo_adj)
    tiles = tile_admission(model, policy, state, tile_q)
    Q = mask.shape[0]
    mask_ct = (mask[:, :, None] & tiles).reshape(Q, -1)
    return mask, mask_ct


# ---------------------------------------------------------------------------
# advance — the one phase-machine transition.
# ---------------------------------------------------------------------------

def advance(policy: SearchPolicy, windows: PhaseWindows, state: PhaseState,
            matched: jnp.ndarray, match_cam: jnp.ndarray,
            horizon: int) -> PhaseState:
    """One Alg.-1 transition for every query at once.

    ``matched`` (Q,) bool and ``match_cam`` (Q,) int32 come from the
    consumer's re-id step.  On a match: re-anchor at (match_cam, f_curr) and
    reset to phase 1.  Otherwise advance the cursor; on window exhaustion
    escalate — phase 1 rewinds to f_q + 1 with relaxed thresholds (phase 2),
    phase 2 either concludes exit or (``exhaustive_final``) enters the
    all-camera phase 3, which runs to the exit threshold.
    """
    matched = matched & ~state.done
    f_q = jnp.where(matched, state.f_curr, state.f_q)
    c_q = jnp.where(matched, match_cam, state.c_q)
    phase = jnp.where(matched, 1, state.phase)

    f_next = state.f_curr + 1
    # behind the frontier: content advances (speed*skip) x realtime, so the
    # live frontier only moves 1/(speed*skip) wall-steps per content step;
    # caught up: the frontier IS the content time.
    rate = 1.0 / policy.replay_rate
    live_next = jnp.where(state.behind, state.live_f + rate,
                          f_next.astype(jnp.float32))
    live_next = jnp.maximum(live_next, f_next.astype(jnp.float32))

    el_next = f_next - f_q
    if policy.scheme in ("all", "geo") or not policy.use_replay:
        done_new = state.done | (el_next > policy.exit_t) | (f_next >= horizon)
        phase_new = phase
        f_new = f_next
    else:
        # phase 1 exhausts its windows -> rewind + relax (phase 2);
        # phase 2 exhausts -> exhaustive pass (phase 3) or conclude exit;
        # phase 3 runs to the exit threshold.  If even the relaxed model
        # admits nothing beyond the self-window, the model's prediction is
        # "exited" — conclude directly, no pointless rewind.
        nothing_relaxed = windows.w_end2[c_q] <= policy.self_window
        exh1 = (phase == 1) & (el_next > windows.w_end1[c_q])
        exh2 = (phase == 2) & (el_next > windows.w_end2[c_q])
        exh3 = (phase >= 3) & (el_next > policy.exit_t)
        if policy.exhaustive_final:
            esc = exh1 | exh2
            done_new = state.done | exh3 | (f_next >= horizon)
        else:
            esc = exh1 & ~nothing_relaxed
            done_new = (state.done | (exh1 & nothing_relaxed) | exh2 | exh3
                        | (f_next >= horizon))
        phase_new = jnp.where(esc, phase + 1, phase)
        f_new = jnp.where(esc, f_q + 1, f_next)

    return PhaseState(
        f_q=f_q,
        c_q=c_q,
        f_curr=jnp.where(state.done, state.f_curr, f_new),
        phase=jnp.where(state.done, state.phase, phase_new),
        live_f=jnp.where(state.done, state.live_f, live_next),
        done=done_new,
    )
