"""The spatio-temporal correlation model M (paper §5.1).

  S(c_s, c_d)            spatial correlation: fraction of c_s's outbound
                         traffic seen next at c_d (row-stochastic incl. exit).
  T(c_s, c_d, [f0, f])   temporal correlation: CDF of inter-camera travel
                         times, evaluated at elapsed time since last sighting.
  f0(c_s, c_d)           earliest historical arrival — search starts there.

  M(c_s, c_d, f) = [S ≥ s_thresh] ∧ [f ≥ f0] ∧ [CDF(elapsed) ≤ 1 - t_thresh]

The model is a few small dense arrays — it is the *only* persistent state of
the ReXCam control plane (paper §7) and is replicated across the serving mesh.
The threshold/query interface (mask construction, window exhaustion,
potential savings) lives in ``repro.core.policy``; the methods below are
thin compatibility delegates over this data container.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

INF_TIME = np.int32(2 ** 30)

#: Model fields whose value at source-camera row ``r`` depends ONLY on
#: transitions departing r (and exits at r): counts/hist->cdf/f0 accumulate
#: per (src, dst) pair, and the S/exit_frac normalizer is the row's own
#: outbound total (``counts[r].sum() + exits[r]``).  ``entry`` is the one
#: GLOBAL field (normalized over every camera's first appearances) — a
#: row-targeted re-profile must always recompute it from the full window.
#: This is the contract that makes ``profiler.merge_reprofiled_rows``
#: bit-identical to a full rebuild on untouched rows.
ROW_LOCAL_FIELDS = ("S", "exit_frac", "cdf", "f0", "counts", "tile_admit")


def splice_rows(model: "SpatioTemporalModel", rows, updates: dict, *,
                entry=None, epoch: int | None = None) -> "SpatioTemporalModel":
    """Replace source-camera rows of the ROW-LOCAL fields with freshly
    profiled blocks, carrying every untouched row bit-for-bit.

    ``updates`` maps field name (in ``ROW_LOCAL_FIELDS``) to a
    ``(len(rows), ...)`` block; splicing keeps the base array's dtype, so a
    float64 profiling block lands exactly as ``build_model``'s own float32
    cast would.  ``entry`` (global — see ``ROW_LOCAL_FIELDS``) and ``epoch``
    replace wholesale.  Array shapes never change, so a hot-swap of the
    result through ``engine.swap_model`` compiles nothing."""
    rows = np.asarray(rows, np.int64)
    repl = {}
    for name, block in updates.items():
        if name not in ROW_LOCAL_FIELDS:
            raise ValueError(f"splice_rows: {name!r} is not row-local "
                             f"(row-local fields: {ROW_LOCAL_FIELDS})")
        base = getattr(model, name)
        if base is None:
            raise ValueError(f"splice_rows: base model has no {name!r} to "
                             f"splice into")
        arr = np.asarray(base).copy()
        arr[rows] = block
        repl[name] = jnp.asarray(arr)
    if entry is not None:
        repl["entry"] = jnp.asarray(entry, jnp.float32)
    if epoch is not None:
        repl["epoch"] = int(epoch)
    return dataclasses.replace(model, **repl)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SpatioTemporalModel:
    """All arrays are jnp; C = number of cameras, NB = travel-time bins."""

    S: jnp.ndarray          # (C, C)  next-camera traffic fractions (rows may sum <1: exits)
    exit_frac: jnp.ndarray  # (C,)    fraction of outbound traffic that exits the network
    cdf: jnp.ndarray        # (C, C, NB) travel-time CDF (fraction arrived by bin b)
    f0: jnp.ndarray         # (C, C)  earliest observed travel time (steps); INF_TIME if none
    entry: jnp.ndarray      # (C,)    P*_c — first-appearance distribution (paper §5.4)
    counts: jnp.ndarray     # (C, C)  raw transition counts (for drift detection / tests)
    bin_width: int = dataclasses.field(metadata=dict(static=True), default=1)
    # model version: 0 = the offline profile, +1 per recalibration hot-swap
    # (runtime.recal).  A data field (not static) so an epoch bump never
    # recompiles the jitted admission/ranking paths; trace records carry it
    # so the differential harness can pin swap timing across the fleet.
    epoch: int = 0
    # CrossRoI-style sub-frame admission: tile_admit[c_s, c_d, t] says
    # whether tile t of camera c_d's T x T grid ever receives c_s -> c_d
    # handoff traffic (smoothed + thresholded entry-region histogram).  A
    # data field so recalibration hot-swaps carry it without recompiling;
    # tile_grid is static (it shapes every tile-path jaxpr).  tile_grid=0
    # means "no tile plane" — camera-granular admission only.
    tile_admit: jnp.ndarray | None = None   # (C, C, T*T) bool, or None
    tile_grid: int = dataclasses.field(metadata=dict(static=True), default=0)
    # True iff tile_admit was LEARNED from profiled positions (vs the
    # engine-synthesized all-tiles-admitted tensor a tile-less model gets).
    # Static because it selects the admission jaxpr: a learned model also
    # activates the self-camera follow neighborhood (the query's last
    # matched tile +- a 1-tile halo instead of the whole frame), which a
    # synthesized model must NOT — the tile differential pins the
    # synthesized path bit-identical to camera-granular serving.
    tile_learned: bool = dataclasses.field(metadata=dict(static=True),
                                           default=False)

    @property
    def n_cams(self) -> int:
        return self.S.shape[0]

    @property
    def n_bins(self) -> int:
        return self.cdf.shape[-1]

    # -- the paper's query interface (delegates to repro.core.policy) -----
    def spatial_mask(self, c_s: jnp.ndarray, s_thresh: float | jnp.ndarray) -> jnp.ndarray:
        """(C,) bool: destinations spatially correlated with c_s."""
        from repro.core import policy
        return policy.spatial_mask(self, c_s, s_thresh)

    def temporal_mask(self, c_s: jnp.ndarray, elapsed: jnp.ndarray,
                      t_thresh: float | jnp.ndarray) -> jnp.ndarray:
        """(C,) bool: destinations temporally correlated at `elapsed` steps."""
        from repro.core import policy
        return policy.temporal_mask(self, c_s, elapsed, t_thresh)

    def correlated(self, c_s: jnp.ndarray, elapsed: jnp.ndarray,
                   s_thresh, t_thresh) -> jnp.ndarray:
        """M(c_s, ·, elapsed): (C,) bool mask over destination cameras."""
        from repro.core import policy
        return policy.correlated(self, c_s, elapsed, s_thresh, t_thresh)

    def window_end(self, s_thresh: float, t_thresh: float) -> jnp.ndarray:
        """(C,) per-source elapsed time at which every admitted destination's
        temporal window has closed (Alg. 1 line 21's exhaustion test)."""
        from repro.core import policy
        return policy.window_end(self, s_thresh, t_thresh)

    # -- §5.4 identity detection needs window-binned temporal mass --------
    def window_transfer(self, window: int, n_windows: int) -> jnp.ndarray:
        """Tw (C, C, n_windows): fraction of c_s->c_d traffic arriving with a
        delay of exactly w windows (w = dt // window)."""
        C, _, NB = self.cdf.shape
        pdf = jnp.diff(self.cdf, axis=-1, prepend=0.0)      # per-bin mass
        bins_per_w = max(window // self.bin_width, 1)
        nw_src = NB // bins_per_w
        trimmed = pdf[:, :, : nw_src * bins_per_w].reshape(C, C, nw_src, bins_per_w).sum(-1)
        if nw_src >= n_windows:
            return trimmed[:, :, :n_windows]
        return jnp.pad(trimmed, ((0, 0), (0, 0), (0, n_windows - nw_src)))

    def potential_savings(self, s_thresh: float, t_thresh: float,
                          weight_by_traffic: bool = True) -> float:
        """Analytic potential (paper §3.2): baseline camera-steps over the
        camera-steps M admits.  Spatial-only: t_thresh=0.  Temporal-only:
        s_thresh=0."""
        from repro.core import policy
        return policy.potential_savings(self, s_thresh, t_thresh,
                                        weight_by_traffic)
