"""Multi-camera identity detection (paper §5.4).

Finding a query with *unknown* location: maintain P[c, w] — the probability
that the (still unscanned) query appears in camera c during time-window w —
propagated through the spatio-temporal model:

    P[c, w] = P*_c·[w = 0] + Σ_{ci, dw>=1} I[ci, w-dw] · P[ci, w-dw]
                                   · S(ci, c) · Tw(ci, c, dw)

where I marks cells not yet scanned.  Each round scans every cell with
P > θ (falling back to the argmax cell so the search always progresses),
pays window·|cells| compute, and stops at the first re-id match.  The same
feature oracle as the tracker decides matches, so precision/recall behave
like the paper's Fig. 17.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.correlation import SpatioTemporalModel
from repro.core.simulate import Visits


@dataclasses.dataclass(frozen=True)
class DetectorParams:
    theta: float = 0.95
    window: int = 20            # steps per window
    match_thresh: float = 0.28
    max_rounds: int = 400
    max_travel_windows: int = 16
    # Surfacing prior: the paper places all prior mass at w=0 (identity
    # *enters* the network at search start).  A query that is already
    # mid-trajectory (our "reported lost" scenario) surfaces at a geometric
    # spread of early windows instead; rho=0 recovers the paper's formula.
    surface_rho: float = 0.97


@partial(jax.jit, static_argnames=("n_windows", "p"))
def propagate(model: SpatioTemporalModel, I: jnp.ndarray, n_windows: int,
              p: DetectorParams) -> jnp.ndarray:
    """P (Q, C, W) given scan indicators I (Q, C, W) (1 = unscanned)."""
    Q, C, W = I.shape
    Tw = model.window_transfer(p.window, p.max_travel_windows)   # (C, C, DW)
    M = model.S[:, :, None] * Tw                                 # (C, C, DW)
    DW = M.shape[-1]

    # occupancy prior: where identities in the network tend to be (inbound
    # traffic distribution), mixed with the entry distribution
    inbound = model.counts.sum(0)
    occupancy = inbound / jnp.maximum(inbound.sum(), 1.0)
    prior = 0.5 * occupancy + 0.5 * model.entry

    def step(carry, w):
        hist = carry                                             # (DW, Q, C) recent I*P
        # contribution from windows w-dw (dw = 1..DW)
        contrib = jnp.einsum("dqi,icd->qc", hist, M)
        base = prior[None, :] * (1 - p.surface_rho) * p.surface_rho ** w             if p.surface_rho > 0 else jnp.where(w == 0, prior[None, :], 0.0)
        P_w = base + contrib
        IP_w = P_w * I[:, :, w]
        hist = jnp.concatenate([IP_w[None], hist[:-1]], axis=0)
        return hist, P_w

    hist0 = jnp.zeros((DW, Q, C), jnp.float32)
    _, Ps = jax.lax.scan(step, hist0, jnp.arange(W))
    return Ps.transpose(1, 2, 0)                                 # (Q, C, W)


def _presence_and_dist(visits: Visits, feats: np.ndarray, q_vids: np.ndarray,
                       window: int, n_windows: int, t_refs=None):
    """Per query: (C, W) true-entity presence and min feature distance over
    windows RELATIVE to the query's reference time (its last sighting — the
    'reported lost at t_ref' frame).  Window w covers
    [t_ref + w*window, t_ref + (w+1)*window)."""
    C = visits.n_cams
    W = n_windows
    Q = len(q_vids)
    q_ent = visits.ent[q_vids]
    q_feat = feats[q_vids]                                       # (Q, D)
    if t_refs is None:
        t_refs = visits.t_out[q_vids]                            # (Q,)
    t_ref = np.broadcast_to(np.asarray(t_refs), (Q,))
    presence = np.zeros((Q, C, W), bool)
    mind = np.full((Q, C, W), np.inf, np.float32)
    d_all = 1.0 - feats @ q_feat.T                               # (V, Q)
    for vid in range(len(visits)):
        c = visits.cam[vid]
        # per-query relative window span of this visit
        w_in = (visits.t_in[vid] - t_ref) // window              # (Q,)
        w_out = (visits.t_out[vid] - t_ref) // window
        for q in range(Q):
            a, b = int(w_in[q]), int(w_out[q])
            if b < 0 or a >= W:
                continue
            a, b = max(a, 0), min(b, W - 1)
            dv = d_all[vid, q]
            sl = mind[q, c, a:b + 1]
            np.minimum(sl, dv, out=sl)
            if visits.ent[vid] == q_ent[q]:
                presence[q, c, a:b + 1] = True
    return presence, mind


def make_detection_queries(visits: Visits, n: int, search_start: int,
                           seed: int = 0, max_delay_windows: int = 48,
                           window: int = 20):
    """Lost-identity scenario (paper §5.4): entities that ENTER the network at
    an unknown time after ``search_start``.  Returns (q_vids, t_refs) where
    q_vids index each entity's first visit and the search reference time is
    ``search_start`` for every query."""
    rng = np.random.default_rng(seed)
    first = {}
    order = np.lexsort((visits.t_in, visits.ent))
    for vid in order[::-1]:
        first[int(visits.ent[vid])] = int(vid)
    horizon = search_start + max_delay_windows * window
    cands = [v for v in first.values()
             if search_start < visits.t_in[v] < horizon]
    rng.shuffle(cands)
    return np.array(cands[:n], np.int32)


def identity_detection(model: SpatioTemporalModel, visits: Visits,
                       feats: np.ndarray, q_vids: np.ndarray,
                       p: DetectorParams, baseline: bool = False,
                       n_windows: int = 64, t_refs=None):
    """Returns dict(cost, recall, precision, rounds).

    ``t_refs``: per-query (or scalar) search start; default = each query's
    last sighting (tracking hand-off).  For the lost-identity scenario pass
    the common search start from ``make_detection_queries``."""
    C = visits.n_cams
    W = n_windows
    Q = len(q_vids)
    presence, mind = _presence_and_dist(visits, feats, q_vids, p.window, W,
                                        t_refs=t_refs)
    match_table = mind < p.match_thresh                          # flagged if scanned
    correct_table = match_table & presence

    I = np.ones((Q, C, W), np.float32)
    found = np.zeros(Q, bool)
    found_correct = np.zeros(Q, bool)
    cost = np.zeros(Q, np.float64)
    n_flagged = np.zeros(Q, np.int64)

    if baseline:
        # scan everything in time order until the query is verifiably found
        # (flags along the way are retrievals the verifier must sift through)
        for q in range(Q):
            for w in range(W):
                cost[q] += C * p.window
                flags = match_table[q, :, w]
                n_flagged[q] += int(flags.sum())
                if (correct_table[q, :, w]).any():
                    found[q] = found_correct[q] = True
                    break
        return _detect_summary(cost, found, found_correct, n_flagged, 0)

    rounds = 0
    active = np.ones(Q, bool)
    for rounds in range(1, p.max_rounds + 1):
        if not active.any():
            break
        P = np.asarray(propagate(model, jnp.asarray(I), W, p))
        P = P * I                                                # only unscanned cells
        for q in np.where(active)[0]:
            # likelihood threshold relative to the current best cell: high
            # theta scans only the most probable cells (cheapest), low theta
            # casts a wider net per round (paper Fig. 17's theta sweep).
            pmax = P[q].max()
            if pmax <= 0:
                active[q] = False
                continue
            cells = P[q] >= p.theta * pmax
            cost[q] += cells.sum() * p.window
            I[q][cells] = 0.0
            flags = match_table[q] & cells
            n_flagged[q] += int(flags.sum())
            if (correct_table[q] & cells).any():
                found[q] = found_correct[q] = True
                active[q] = False
            elif I[q].sum() == 0:
                active[q] = False                                # exhausted
    return _detect_summary(cost, found, found_correct, n_flagged, rounds)


def _detect_summary(cost, found, found_correct, n_flagged, rounds):
    return {
        "cost": float(cost.sum()),
        "recall": float(found_correct.mean()),
        "precision": float(found_correct.sum() / max(n_flagged.sum(), 1)),
        "found_rate": float(found.mean()),
        "rounds": int(rounds),
    }
