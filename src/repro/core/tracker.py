"""Cross-camera identity tracking — the paper's Algorithm 1 + replay (§5.2-5.3).

The whole query set runs as ONE batched ``lax.while_loop`` (state arrays are
(Q, ...)): each step every live query

  1. asks the spatio-temporal model M which cameras are correlated with its
     last-seen camera at the current elapsed time (phase 1), with thresholds
     relaxed x10 (phase 2 = replay), or searches everything (phase 3),
  2. pays compute cost = number of admitted camera-frames,
  3. ranks the admitted galleries by feature distance to its query
     representation (the re-id step the inference plane executes),
  4. on a match: updates its representation (EMA), resets to phase 1 at the
     match camera; on exit-threshold expiry: escalates phase.

Replay lag follows §5.3: phase>=2 processes *historical* frames; skip mode
(process 1-in-k) and fast-forward mode (k x throughput) trade cost, accuracy
and delay differently — both are modeled exactly as the deployment would
behave (skip mode can miss short visits; ff mode costs extra compute).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.correlation import SpatioTemporalModel
from repro.core.simulate import Visits

BIG = jnp.float32(1e9)


@dataclasses.dataclass(frozen=True)
class TrackerParams:
    """Algorithm-1 parameters.

    Phase semantics (paper §5.2-5.3, Alg. 1 line 21): phase 1 searches the
    normal spatio-temporal windows; when those are *exhausted* (all admitted
    cameras past their travel-time windows) the tracker rewinds to f_q+1 and
    replays with thresholds relaxed x ``relax_factor`` (phase 2).  When the
    relaxed windows are exhausted too, the model's prediction is that q has
    exited; ``exhaustive_final=True`` additionally runs the paper's literal
    "search the entire network until the exit threshold" pass (phase 3) —
    off by default since the paper's own reported delays (~3s) show this
    all-camera terminal sweep cannot be running per query (DESIGN.md §7).
    ``exit_t`` is the baseline's "maximum duration" (§3.2) and an upper bound
    on every phase.
    """

    scheme: str = "rexcam"          # rexcam | all | geo | spatial_only
    s_thresh: float = 0.05
    t_thresh: float = 0.02
    exit_t: int = 240               # max steps without a match (baseline window)
    match_thresh: float = 0.28      # cosine-distance acceptance
    feat_alpha: float = 0.25        # query-representation EMA rate
    relax_factor: float = 10.0      # replay threshold relaxation (paper: x10)
    replay_speed: float = 1.0       # >1 = parallelism ("ff") mode
    replay_skip: int = 1            # >1 = frame-skip mode
    use_replay: bool = True
    exhaustive_final: bool = False  # paper-literal terminal all-camera pass
    self_window: int = 6            # steps the last-seen camera stays admitted

    @property
    def use_spatial(self) -> bool:
        return self.scheme in ("rexcam", "spatial_only")

    @property
    def use_temporal(self) -> bool:
        return self.scheme == "rexcam" and self.t_thresh > 0.0


@dataclasses.dataclass
class TrackResult:
    cost: np.ndarray          # (Q,) camera-frames processed
    n_match: np.ndarray       # (Q,) matches flagged
    n_correct: np.ndarray     # (Q,) matches that were the true entity
    visit_hits: np.ndarray    # (Q, Vmax) GT visits retrieved
    gt_count: np.ndarray      # (Q,) GT visits available
    delay: np.ndarray         # (Q,) final lag (steps)
    rescued: np.ndarray       # (Q,) matches recovered by replay (phase>=2)
    rescue_pairs: np.ndarray  # (C, C) replay-rescue counts (drift detection §6)

    @property
    def total_cost(self) -> float:
        return float(self.cost.sum())

    @property
    def recall(self) -> float:
        return float(self.visit_hits.sum() / max(self.gt_count.sum(), 1))

    @property
    def precision(self) -> float:
        return float(self.n_correct.sum() / max(self.n_match.sum(), 1))

    @property
    def mean_delay(self) -> float:
        return float(self.delay.mean())

    def summary(self) -> dict:
        return {"cost": self.total_cost, "recall": self.recall,
                "precision": self.precision, "delay": self.mean_delay,
                "rescued": int(self.rescued.sum())}


def make_queries(visits: Visits, n_queries: int, seed: int = 0,
                 min_future_visits: int = 1, vmax: int = 32):
    """Sample query identities (paper §8.1C: drawn from the test partition).

    Returns (q_vids (Q,), gt_vids (Q, vmax) padded -1)."""
    rng = np.random.default_rng(seed)
    by_ent: dict[int, list[int]] = {}
    order = np.lexsort((visits.t_in, visits.ent))
    for vid in order:
        by_ent.setdefault(int(visits.ent[vid]), []).append(int(vid))
    candidates = [vs[0] for vs in by_ent.values() if len(vs) >= 1 + min_future_visits]
    rng.shuffle(candidates)
    chosen = candidates[:n_queries]
    q_vids = np.array(chosen, np.int32)
    gt = np.full((len(chosen), vmax), -1, np.int32)
    for i, v0 in enumerate(chosen):
        e = int(visits.ent[v0])
        future = [v for v in by_ent[e] if visits.t_in[v] > visits.t_out[v0]]
        gt[i, :min(len(future), vmax)] = future[:vmax]
    return q_vids, gt


@partial(jax.jit, static_argnames=("p", "horizon"))
def _track_jit(model: SpatioTemporalModel, gallery, feats, visit_ent,
               visit_cam, visit_tout, q_vids, gt_vids, geo_adj, p: TrackerParams,
               horizon: int):
    Q = q_vids.shape[0]
    C, T, K = gallery.shape
    Vmax = gt_vids.shape[1]

    q_ent = visit_ent[q_vids]                       # (Q,)
    c_q0 = visit_cam[q_vids]
    f_q0 = visit_tout[q_vids]

    state = dict(
        f_q=f_q0.astype(jnp.int32),
        c_q=c_q0.astype(jnp.int32),
        f_curr=(f_q0 + 1).astype(jnp.int32),
        phase=jnp.ones((Q,), jnp.int32),
        q_feat=feats[q_vids],
        live_f=(f_q0 + 1).astype(jnp.float32),
        cost=jnp.zeros((Q,), jnp.float32),
        n_match=jnp.zeros((Q,), jnp.int32),
        n_correct=jnp.zeros((Q,), jnp.int32),
        visit_hits=jnp.zeros((Q, Vmax), jnp.bool_),
        rescued=jnp.zeros((Q,), jnp.int32),
        rescue_pairs=jnp.zeros((C, C), jnp.int32),
        done=jnp.zeros((Q,), jnp.bool_),
        iters=jnp.zeros((), jnp.int32),
    )

    max_iters = 4 * horizon

    # Per-source window-exhaustion horizons for phase 1 and the relaxed phase 2.
    w_end1 = model.window_end(p.s_thresh, p.t_thresh if p.use_temporal else 0.0)
    w_end2 = model.window_end(p.s_thresh / p.relax_factor,
                              (p.t_thresh / p.relax_factor) if p.use_temporal else 0.0)
    w_end1 = jnp.minimum(jnp.maximum(w_end1, p.self_window), p.exit_t)
    w_end2 = jnp.minimum(jnp.maximum(w_end2, p.self_window), p.exit_t)

    def cond(st):
        return (~st["done"]).any() & (st["iters"] < max_iters)

    def body(st):
        f_curr, f_q, c_q, phase = st["f_curr"], st["f_q"], st["c_q"], st["phase"]
        live = ~st["done"]
        elapsed = f_curr - f_q

        # last-seen camera stays admitted briefly (single-camera follow)
        self_mask = jax.nn.one_hot(c_q, C, dtype=jnp.bool_) & \
            (elapsed <= p.self_window)[:, None]

        # --- camera admission mask (Q, C) ---
        if p.scheme == "all":
            mask = jnp.ones((Q, C), bool)
        elif p.scheme == "geo":
            mask = geo_adj[c_q] | self_mask
        else:
            relax = jnp.where(phase >= 2, 1.0 / p.relax_factor, 1.0)
            s_th = p.s_thresh * relax
            sp = model.S[c_q] >= s_th[:, None] if p.use_spatial else jnp.ones((Q, C), bool)
            if p.use_temporal:
                t_th = p.t_thresh * relax
                b = jnp.clip(elapsed // model.bin_width, 0, model.n_bins - 1)
                # exclusive CDF: fraction arrived strictly before this bin
                arrived = jnp.where((b > 0)[:, None],
                                    model.cdf[c_q, :, jnp.maximum(b - 1, 0)], 0.0)
                started = elapsed[:, None] >= model.f0[c_q]
                tp = started & (arrived <= 1.0 - t_th[:, None])
            else:
                tp = jnp.ones((Q, C), bool)
            mask = (sp & tp) | self_mask
            mask = jnp.where(phase[:, None] >= 3, True, mask)     # exhaustive pass

        # lag-aware processing: behind the live frontier -> historical frames,
        # optionally sampled 1-in-k (skip mode)
        behind = f_curr.astype(jnp.float32) < st["live_f"] - 0.5
        process = jnp.where(behind & (p.replay_skip > 1),
                            (f_curr - f_q) % p.replay_skip == 0, True)
        mask = mask & process[:, None] & live[:, None]

        st = dict(st, cost=st["cost"] + mask.sum(1).astype(jnp.float32))

        # --- gallery ranking (the re-id step) ---
        f_idx = jnp.clip(f_curr, 0, T - 1)
        vids = jnp.take(gallery, f_idx, axis=1)                   # (C, Q, K)
        vids = vids.transpose(1, 0, 2)                            # (Q, C, K)
        valid = (vids >= 0) & mask[:, :, None]
        g = feats[jnp.maximum(vids, 0)]                           # (Q, C, K, D)
        d = 1.0 - jnp.einsum("qckd,qd->qck", g, st["q_feat"])
        d = jnp.where(valid, d, BIG)
        flat = d.reshape(Q, C * K)
        best = jnp.argmin(flat, axis=1)
        best_d = jnp.take_along_axis(flat, best[:, None], 1)[:, 0]
        best_cam = (best // K).astype(jnp.int32)
        best_vid = jnp.take_along_axis(vids.reshape(Q, C * K), best[:, None], 1)[:, 0]
        matched = (best_d < p.match_thresh) & live

        # --- match bookkeeping ---
        ent_b = visit_ent[jnp.maximum(best_vid, 0)]
        correct = matched & (ent_b == q_ent)
        hits = st["visit_hits"] | (correct[:, None] & (gt_vids == best_vid[:, None]))
        fb = feats[jnp.maximum(best_vid, 0)]
        new_feat = (1 - p.feat_alpha) * st["q_feat"] + p.feat_alpha * fb
        new_feat = new_feat / jnp.maximum(
            jnp.linalg.norm(new_feat, axis=-1, keepdims=True), 1e-9)
        was_replay = matched & (phase >= 2)
        rp = st["rescue_pairs"].at[c_q, best_cam].add(was_replay.astype(jnp.int32))

        st = dict(
            st,
            n_match=st["n_match"] + matched.astype(jnp.int32),
            n_correct=st["n_correct"] + correct.astype(jnp.int32),
            visit_hits=hits,
            rescued=st["rescued"] + was_replay.astype(jnp.int32),
            rescue_pairs=rp,
            q_feat=jnp.where(matched[:, None], new_feat, st["q_feat"]),
            f_q=jnp.where(matched, f_curr, f_q),
            c_q=jnp.where(matched, best_cam, c_q),
            phase=jnp.where(matched, 1, phase),
        )

        # --- time advance + phase escalation ---
        f_next = f_curr + 1
        # behind the frontier: content advances (speed*skip) x realtime, so the
        # live frontier only moves 1/(speed*skip) wall-steps per content step;
        # caught up: the frontier IS the content time.
        rate = 1.0 / (p.replay_speed * p.replay_skip)
        live_next = jnp.where(behind, st["live_f"] + rate, f_next.astype(jnp.float32))
        live_next = jnp.maximum(live_next, f_next.astype(jnp.float32))

        el_next = f_next - st["f_q"]
        if p.scheme in ("all", "geo") or not p.use_replay:
            done_new = st["done"] | (el_next > p.exit_t) | (f_next >= horizon)
            phase_new = st["phase"]
            f_new = f_next
        else:
            # phase 1 exhausts its windows -> rewind + relax (phase 2);
            # phase 2 exhausts -> exhaustive pass (phase 3) or conclude exit;
            # phase 3 runs to the exit threshold.  If even the relaxed model
            # admits nothing beyond the self-window, the model's prediction is
            # "exited" — conclude directly, no pointless rewind.
            nothing_relaxed = w_end2[st["c_q"]] <= p.self_window
            exh1 = (st["phase"] == 1) & (el_next > w_end1[st["c_q"]])
            exh2 = (st["phase"] == 2) & (el_next > w_end2[st["c_q"]])
            exh3 = (st["phase"] >= 3) & (el_next > p.exit_t)
            if p.exhaustive_final:
                esc = exh1 | exh2
                done_new = st["done"] | exh3 | (f_next >= horizon)
            else:
                esc = exh1 & ~nothing_relaxed
                done_new = (st["done"] | (exh1 & nothing_relaxed) | exh2 | exh3
                            | (f_next >= horizon))
            phase_new = jnp.where(esc, st["phase"] + 1, st["phase"])
            f_new = jnp.where(esc, st["f_q"] + 1, f_next)

        return dict(
            st,
            f_curr=jnp.where(st["done"], f_curr, f_new),
            phase=jnp.where(st["done"], phase, phase_new),
            live_f=jnp.where(st["done"], st["live_f"], live_next),
            done=done_new,
            iters=st["iters"] + 1,
        )

    st = jax.lax.while_loop(cond, body, state)
    delay = jnp.maximum(st["live_f"] - st["f_curr"].astype(jnp.float32), 0.0)
    return st, delay


def track_queries(model: SpatioTemporalModel, visits: Visits, gallery,
                  feats, q_vids, gt_vids, p: TrackerParams,
                  geo_adj=None) -> TrackResult:
    C = visits.n_cams
    if geo_adj is None:
        geo_adj = np.ones((C, C), bool)
    st, delay = _track_jit(
        model,
        jnp.asarray(gallery),
        jnp.asarray(feats),
        jnp.asarray(visits.ent, jnp.int32),
        jnp.asarray(visits.cam, jnp.int32),
        jnp.asarray(visits.t_out, jnp.int32),
        jnp.asarray(q_vids, jnp.int32),
        jnp.asarray(gt_vids, jnp.int32),
        jnp.asarray(geo_adj),
        p,
        visits.horizon,
    )
    gt_count = (np.asarray(gt_vids) >= 0).sum(1)
    return TrackResult(
        cost=np.asarray(st["cost"]),
        n_match=np.asarray(st["n_match"]),
        n_correct=np.asarray(st["n_correct"]),
        visit_hits=np.asarray(st["visit_hits"]),
        gt_count=gt_count,
        delay=np.asarray(delay),
        rescued=np.asarray(st["rescued"]),
        rescue_pairs=np.asarray(st["rescue_pairs"]),
    )
