"""Cross-camera identity tracking — the paper's Algorithm 1 + replay (§5.2-5.3).

The whole query set runs as ONE batched ``lax.while_loop`` (state arrays are
(Q, ...)): each step every live query

  1. asks the shared control plane (``repro.core.policy.admit``) which
     cameras are correlated with its last-seen camera at the current elapsed
     time (phase 1), with thresholds relaxed x10 (phase 2 = replay), or
     searches everything (phase 3),
  2. pays compute cost = number of admitted camera-frames,
  3. ranks the admitted galleries by feature distance to its query
     representation (the re-id step the inference plane executes),
  4. hands the match outcome to ``repro.core.policy.advance`` — the same
     phase machine the live serving engine runs.

Replay lag follows §5.3: phase>=2 processes *historical* frames; skip mode
(process 1-in-k) and fast-forward mode (k x throughput) trade cost, accuracy
and delay differently — both are modeled exactly as the deployment would
behave (skip mode can miss short visits; ff mode costs extra compute).

``SearchPolicy`` supersedes the old ``TrackerParams``; the legacy name is
kept as an alias for existing callers.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.correlation import SpatioTemporalModel
from repro.core.policy import (PhaseState, SearchPolicy, admit, advance,
                               phase_windows)
from repro.core.simulate import Visits

BIG = jnp.float32(1e9)

# Legacy alias: the batched tracker's parameters ARE the shared SearchPolicy.
TrackerParams = SearchPolicy


@dataclasses.dataclass
class TrackResult:
    cost: np.ndarray          # (Q,) camera-frames processed
    n_match: np.ndarray       # (Q,) matches flagged
    n_correct: np.ndarray     # (Q,) matches that were the true entity
    visit_hits: np.ndarray    # (Q, Vmax) GT visits retrieved
    gt_count: np.ndarray      # (Q,) GT visits available
    delay: np.ndarray         # (Q,) final lag (steps)
    rescued: np.ndarray       # (Q,) matches recovered by replay (phase>=2)
    rescue_pairs: np.ndarray  # (C, C) replay-rescue counts (drift detection §6)

    @property
    def total_cost(self) -> float:
        return float(self.cost.sum())

    @property
    def recall(self) -> float:
        return float(self.visit_hits.sum() / max(self.gt_count.sum(), 1))

    @property
    def precision(self) -> float:
        return float(self.n_correct.sum() / max(self.n_match.sum(), 1))

    @property
    def mean_delay(self) -> float:
        return float(self.delay.mean())

    def summary(self) -> dict:
        return {"cost": self.total_cost, "recall": self.recall,
                "precision": self.precision, "delay": self.mean_delay,
                "rescued": int(self.rescued.sum())}


def make_queries(visits: Visits, n_queries: int, seed: int = 0,
                 min_future_visits: int = 1, vmax: int = 32):
    """Sample query identities (paper §8.1C: drawn from the test partition).

    Returns (q_vids (Q,), gt_vids (Q, vmax) padded -1)."""
    rng = np.random.default_rng(seed)
    by_ent: dict[int, list[int]] = {}
    order = np.lexsort((visits.t_in, visits.ent))
    for vid in order:
        by_ent.setdefault(int(visits.ent[vid]), []).append(int(vid))
    candidates = [vs[0] for vs in by_ent.values() if len(vs) >= 1 + min_future_visits]
    rng.shuffle(candidates)
    chosen = candidates[:n_queries]
    q_vids = np.array(chosen, np.int32)
    gt = np.full((len(chosen), vmax), -1, np.int32)
    for i, v0 in enumerate(chosen):
        e = int(visits.ent[v0])
        future = [v for v in by_ent[e] if visits.t_in[v] > visits.t_out[v0]]
        gt[i, :min(len(future), vmax)] = future[:vmax]
    return q_vids, gt


def _rank_galleries(gallery, feats, q_feat, f_curr, mask, match_thresh):
    """The re-id step: best (distance, camera, vid) per query over the
    admitted camera-frames at each query's content cursor."""
    Q = q_feat.shape[0]
    C, T, K = gallery.shape
    f_idx = jnp.clip(f_curr, 0, T - 1)
    vids = jnp.take(gallery, f_idx, axis=1)                   # (C, Q, K)
    vids = vids.transpose(1, 0, 2)                            # (Q, C, K)
    valid = (vids >= 0) & mask[:, :, None]
    g = feats[jnp.maximum(vids, 0)]                           # (Q, C, K, D)
    d = 1.0 - jnp.einsum("qckd,qd->qck", g, q_feat)
    d = jnp.where(valid, d, BIG)
    flat = d.reshape(Q, C * K)
    best = jnp.argmin(flat, axis=1)
    best_d = jnp.take_along_axis(flat, best[:, None], 1)[:, 0]
    best_cam = (best // K).astype(jnp.int32)
    best_vid = jnp.take_along_axis(vids.reshape(Q, C * K), best[:, None], 1)[:, 0]
    matched = best_d < match_thresh
    return matched, best_cam, best_vid


def _track_step(st, model, gallery, feats, visit_ent, q_ent, gt_vids, geo_adj,
                windows, p: SearchPolicy, horizon: int):
    """One batched Alg.-1 step shared by the while-loop and the trace scan."""
    ps: PhaseState = st["ps"]

    mask = admit(model, p, ps, geo_adj)                       # (Q, C)
    st = dict(st, cost=st["cost"] + mask.sum(1).astype(jnp.float32))

    matched, best_cam, best_vid = _rank_galleries(
        gallery, feats, st["q_feat"], ps.f_curr, mask, p.match_thresh)
    matched = matched & ~ps.done

    # --- match bookkeeping ---
    ent_b = visit_ent[jnp.maximum(best_vid, 0)]
    correct = matched & (ent_b == q_ent)
    hits = st["visit_hits"] | (correct[:, None] & (gt_vids == best_vid[:, None]))
    fb = feats[jnp.maximum(best_vid, 0)]
    new_feat = (1 - p.feat_alpha) * st["q_feat"] + p.feat_alpha * fb
    new_feat = new_feat / jnp.maximum(
        jnp.linalg.norm(new_feat, axis=-1, keepdims=True), 1e-9)
    was_replay = matched & (ps.phase >= 2)
    rp = st["rescue_pairs"].at[ps.c_q, best_cam].add(was_replay.astype(jnp.int32))

    st = dict(
        st,
        n_match=st["n_match"] + matched.astype(jnp.int32),
        n_correct=st["n_correct"] + correct.astype(jnp.int32),
        visit_hits=hits,
        rescued=st["rescued"] + was_replay.astype(jnp.int32),
        rescue_pairs=rp,
        q_feat=jnp.where(matched[:, None], new_feat, st["q_feat"]),
        ps=advance(p, windows, ps, matched, best_cam, horizon),
        iters=st["iters"] + 1,
    )
    trace = dict(f_curr=ps.f_curr, phase=ps.phase, live=~ps.done, mask=mask,
                 matched=matched, match_cam=best_cam)
    return st, trace


def _init_state(feats, visit_cam, visit_tout, q_vids, gt_vids, n_cams):
    Q = q_vids.shape[0]
    Vmax = gt_vids.shape[1]
    return dict(
        rescue_pairs=jnp.zeros((n_cams, n_cams), jnp.int32),
        ps=PhaseState.init(visit_cam[q_vids], visit_tout[q_vids]),
        q_feat=feats[q_vids],
        cost=jnp.zeros((Q,), jnp.float32),
        n_match=jnp.zeros((Q,), jnp.int32),
        n_correct=jnp.zeros((Q,), jnp.int32),
        visit_hits=jnp.zeros((Q, Vmax), jnp.bool_),
        rescued=jnp.zeros((Q,), jnp.int32),
        iters=jnp.zeros((), jnp.int32),
    )


@partial(jax.jit, static_argnames=("p", "horizon"))
def _track_jit(model: SpatioTemporalModel, gallery, feats, visit_ent,
               visit_cam, visit_tout, q_vids, gt_vids, geo_adj, p: SearchPolicy,
               horizon: int):
    C = gallery.shape[0]
    q_ent = visit_ent[q_vids]                       # (Q,)
    state = _init_state(feats, visit_cam, visit_tout, q_vids, gt_vids, C)
    windows = phase_windows(model, p)
    max_iters = 4 * horizon

    def cond(st):
        return (~st["ps"].done).any() & (st["iters"] < max_iters)

    def body(st):
        st, _ = _track_step(st, model, gallery, feats, visit_ent, q_ent,
                            gt_vids, geo_adj, windows, p, horizon)
        return st

    st = jax.lax.while_loop(cond, body, state)
    ps = st["ps"]
    delay = jnp.maximum(ps.live_f - ps.f_curr.astype(jnp.float32), 0.0)
    return st, delay


@partial(jax.jit, static_argnames=("p", "horizon", "n_steps"))
def _trace_jit(model: SpatioTemporalModel, gallery, feats, visit_ent,
               visit_cam, visit_tout, q_vids, gt_vids, geo_adj, p: SearchPolicy,
               horizon: int, n_steps: int):
    """Fixed-length scan over the SAME step function, recording per-step
    admission masks and phase transitions (the tracker↔engine parity hook)."""
    C = gallery.shape[0]
    q_ent = visit_ent[q_vids]
    state = _init_state(feats, visit_cam, visit_tout, q_vids, gt_vids, C)
    windows = phase_windows(model, p)

    def step(st, _):
        return _track_step(st, model, gallery, feats, visit_ent, q_ent,
                           gt_vids, geo_adj, windows, p, horizon)

    st, trace = jax.lax.scan(step, state, None, length=n_steps)
    return st, trace


def track_queries(model: SpatioTemporalModel, visits: Visits, gallery,
                  feats, q_vids, gt_vids, p: SearchPolicy,
                  geo_adj=None) -> TrackResult:
    C = visits.n_cams
    if geo_adj is None:
        geo_adj = np.ones((C, C), bool)
    st, delay = _track_jit(
        model,
        jnp.asarray(gallery),
        jnp.asarray(feats),
        jnp.asarray(visits.ent, jnp.int32),
        jnp.asarray(visits.cam, jnp.int32),
        jnp.asarray(visits.t_out, jnp.int32),
        jnp.asarray(q_vids, jnp.int32),
        jnp.asarray(gt_vids, jnp.int32),
        jnp.asarray(geo_adj),
        p,
        visits.horizon,
    )
    gt_count = (np.asarray(gt_vids) >= 0).sum(1)
    return TrackResult(
        cost=np.asarray(st["cost"]),
        n_match=np.asarray(st["n_match"]),
        n_correct=np.asarray(st["n_correct"]),
        visit_hits=np.asarray(st["visit_hits"]),
        gt_count=gt_count,
        delay=np.asarray(delay),
        rescued=np.asarray(st["rescued"]),
        rescue_pairs=np.asarray(st["rescue_pairs"]),
    )


def trace_queries(model: SpatioTemporalModel, visits: Visits, gallery,
                  feats, q_vids, gt_vids, p: SearchPolicy, geo_adj=None,
                  n_steps: int | None = None) -> dict:
    """Run the tracker for a fixed number of steps, returning the per-step
    trace: f_curr/phase/live (n_steps, Q), mask (n_steps, Q, C), matched and
    match_cam (n_steps, Q).  Steps where ``live`` is False are padding past a
    query's termination."""
    C = visits.n_cams
    if geo_adj is None:
        geo_adj = np.ones((C, C), bool)
    if n_steps is None:
        n_steps = 4 * visits.horizon
    _, trace = _trace_jit(
        model,
        jnp.asarray(gallery),
        jnp.asarray(feats),
        jnp.asarray(visits.ent, jnp.int32),
        jnp.asarray(visits.cam, jnp.int32),
        jnp.asarray(visits.t_out, jnp.int32),
        jnp.asarray(q_vids, jnp.int32),
        jnp.asarray(gt_vids, jnp.int32),
        jnp.asarray(geo_adj),
        p,
        visits.horizon,
        n_steps,
    )
    return {k: np.asarray(v) for k, v in trace.items()}
