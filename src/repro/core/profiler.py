"""Offline profiling of spatio-temporal correlations (paper §6).

Input is the output of an MTMC tracker over historical video: per detected
entity instance a (camera, frame, entity) tuple — here consolidated into
*visits* (entity, camera, t_in, t_out).  The profiler:

  1. orders each entity's visits in time,
  2. extracts consecutive-visit transitions (c_s -> c_d, dt),
  3. accumulates transition counts, travel-time histograms, first-arrival
     times, entry distribution,
  4. normalizes into a :class:`SpatioTemporalModel`.

Frame-sampled profiling (paper §8.4): ``sample_every=k`` emulates labeling
only every k-th frame — visits that no multiple of k intersects are dropped
and the surviving timestamps are quantized, exactly the degradation a
cheaper MTMC pass would produce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.correlation import INF_TIME, SpatioTemporalModel


def subsample_visits(ent, cam, t_in, t_out, sample_every: int):
    """Emulate frame-sampled MTMC labeling (returns filtered+quantized visits)."""
    if sample_every <= 1:
        return ent, cam, t_in, t_out
    k = sample_every
    first_tick = ((t_in + k - 1) // k) * k          # first labeled frame >= t_in
    seen = first_tick <= t_out
    q_in = first_tick
    q_out = (t_out // k) * k
    return ent[seen], cam[seen], q_in[seen], q_out[seen]


def transitions_from_visits(ent, cam, t_in, t_out):
    """Consecutive-visit transitions per entity.

    Returns (src_cam, dst_cam, dt, src_is_last, first_cam_of_entity) where the
    first two + dt are per *transition* and the last two are per *visit* flags
    used for exit/entry statistics.
    """
    order = np.lexsort((np.asarray(t_in), np.asarray(ent)))
    e = np.asarray(ent)[order]
    c = np.asarray(cam)[order]
    ti = np.asarray(t_in)[order]
    to = np.asarray(t_out)[order]
    same = e[1:] == e[:-1]
    src = c[:-1][same]
    dst = c[1:][same]
    dt = (ti[1:] - to[:-1])[same]
    dt = np.maximum(dt, 0)
    # exits: a visit is terminal if it is the last of its entity
    is_last = np.ones(len(e), bool)
    is_last[:-1] = ~same
    is_first = np.ones(len(e), bool)
    is_first[1:] = ~same
    return src, dst, dt, c[is_last], c[is_first]


def tile_admit_from_visits(ent, cam, t_in, tile_xy, n_cams: int,
                           tile_grid: int, tile_keep: float = 1.0,
                           rows=None):
    """Learn per directed camera-pair entry-region masks on a T x T grid.

    For every consecutive-visit transition (c_s -> c_d) the DESTINATION
    visit's tile is histogrammed into ``hist[c_s, c_d, tile]``; each pair's
    histogram is thresholded to the smallest tile set covering ``tile_keep``
    of its observed mass, then dilated by one tile in every direction (a 3x3
    halo) so detections that jitter across a tile boundary stay admitted.
    Pairs with NO profiled transitions admit every tile — never-observed
    does not mean never-possible, and whole-camera admission already
    gates them spatially/temporally.

    Returns a (C, C, T*T) bool ndarray — or, with ``rows=`` (sorted source
    camera ids), only those source rows as a (len(rows), C, T*T) block:
    transitions departing other cameras are dropped before the histogram
    and the per-pair thresholding loop only visits the requested rows,
    which is what makes a row-targeted re-profile cheap
    (``merge_reprofiled_rows``).  Each (s, d) pair's mask depends only on
    that pair's own transitions, so the block is bit-identical to the
    corresponding rows of a full pass.
    """
    from repro.core.simulate import tile_index

    C, T = n_cams, tile_grid
    order = np.lexsort((np.asarray(t_in), np.asarray(ent)))
    e = np.asarray(ent)[order]
    c = np.asarray(cam)[order]
    same = e[1:] == e[:-1]
    src = c[:-1][same]
    dst = c[1:][same]
    dst_tile = tile_index(np.asarray(tile_xy)[order][1:][same], T)

    if rows is None:
        n_rows, row_of = C, np.arange(C)
    else:
        rows = np.asarray(rows, np.int64)
        n_rows = len(rows)
        row_of = np.full(C, -1, np.int64)        # source cam -> block row
        row_of[rows] = np.arange(n_rows)
        keep = row_of[src] >= 0
        src, dst, dst_tile = src[keep], dst[keep], dst_tile[keep]

    hist = np.zeros((n_rows, C, T * T), np.float64)
    np.add.at(hist, (row_of[src], dst, dst_tile), 1.0)

    total = hist.sum(-1)                         # per-pair transition counts
    admit = np.ones((n_rows, C, T * T), bool)    # unobserved pairs: admit all
    observed = np.argwhere(total > 0)
    for s, d in observed:
        h = hist[s, d]
        # smallest tile set covering tile_keep of the pair's observed mass
        ranked = np.argsort(-h, kind="stable")
        cum = np.cumsum(h[ranked])
        n_keep = int(np.searchsorted(cum, tile_keep * total[s, d] - 1e-9)) + 1
        core = np.zeros(T * T, bool)
        core[ranked[:n_keep]] = h[ranked[:n_keep]] > 0
        # 3x3 dilation halo on the T x T grid
        g = core.reshape(T, T)
        out = g.copy()
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                ys = slice(max(dy, 0), T + min(dy, 0))
                yd = slice(max(-dy, 0), T + min(-dy, 0))
                xs = slice(max(dx, 0), T + min(dx, 0))
                xd = slice(max(-dx, 0), T + min(-dx, 0))
                out[yd, xd] |= g[ys, xs]
        admit[s, d] = out.reshape(T * T)
    return admit


def build_model(ent, cam, t_in, t_out, n_cams: int, *, n_bins: int = 256,
                bin_width: int = 1, sample_every: int = 1,
                time_limit: int | None = None,
                epoch: int = 0, tile_xy=None, tile_grid: int = 0,
                tile_keep: float = 1.0) -> SpatioTemporalModel:
    """Profile a visit table into a SpatioTemporalModel.

    ``time_limit`` restricts profiling to visits starting before it (paper
    §8.4 profiles on a prefix partition of the data).  ``epoch`` stamps the
    model version (0 = the offline profile; ``runtime.recal`` bumps it on
    every recalibration hot-swap).  ``tile_grid=T`` with per-visit
    normalized positions ``tile_xy`` additionally learns the CrossRoI-style
    (C, C, T*T) entry-region admit tensor (``tile_admit_from_visits``);
    ``tile_keep`` is that pass's mass-coverage threshold.
    """
    ent, cam, t_in, t_out = map(np.asarray, (ent, cam, t_in, t_out))
    if tile_xy is not None:
        tile_xy = np.asarray(tile_xy)
    if time_limit is not None:
        keep = t_in < time_limit
        ent, cam, t_in, t_out = ent[keep], cam[keep], t_in[keep], t_out[keep]
        if tile_xy is not None:
            tile_xy = tile_xy[keep]
    if sample_every > 1 and tile_xy is not None:
        # keep the tile labels in lockstep with the frame-sampled visit
        # filter (same `seen` predicate subsample_visits applies)
        k = sample_every
        tile_xy = tile_xy[((t_in + k - 1) // k) * k <= t_out]
    ent, cam, t_in, t_out = subsample_visits(ent, cam, t_in, t_out, sample_every)

    src, dst, dt, exit_cams, entry_cams = transitions_from_visits(ent, cam, t_in, t_out)

    tile_admit = None
    if tile_grid > 0:
        if tile_xy is None:
            raise ValueError("tile_grid > 0 requires per-visit tile_xy "
                             "positions (Visits.tile_xy)")
        tile_admit = tile_admit_from_visits(ent, cam, t_in, tile_xy, n_cams,
                                            tile_grid, tile_keep)

    C, NB = n_cams, n_bins
    counts = np.zeros((C, C), np.float64)
    np.add.at(counts, (src, dst), 1.0)

    hist = np.zeros((C, C, NB), np.float64)
    b = np.clip(dt // bin_width, 0, NB - 1)
    np.add.at(hist, (src, dst, b), 1.0)

    f0 = np.full((C, C), int(INF_TIME), np.int64)
    np.minimum.at(f0, (src, dst), dt)

    exits = np.zeros((C,), np.float64)
    np.add.at(exits, exit_cams, 1.0)
    entry = np.zeros((C,), np.float64)
    np.add.at(entry, entry_cams, 1.0)

    out_total = counts.sum(1) + exits                # all traffic leaving each camera
    denom = np.maximum(out_total, 1.0)
    S = counts / denom[:, None]
    exit_frac = exits / denom

    cdf = np.cumsum(hist, axis=-1)
    cdf = cdf / np.maximum(cdf[..., -1:], 1.0)

    entry = entry / max(entry.sum(), 1.0)

    return SpatioTemporalModel(
        S=jnp.asarray(S, jnp.float32),
        exit_frac=jnp.asarray(exit_frac, jnp.float32),
        cdf=jnp.asarray(cdf, jnp.float32),
        f0=jnp.asarray(np.minimum(f0, int(INF_TIME)), jnp.int32),
        entry=jnp.asarray(entry, jnp.float32),
        counts=jnp.asarray(counts, jnp.float32),
        bin_width=bin_width,
        epoch=epoch,
        tile_admit=None if tile_admit is None else jnp.asarray(tile_admit),
        tile_grid=tile_grid,
        tile_learned=tile_admit is not None,
    )


def merge_reprofiled_rows(old: SpatioTemporalModel, ent, cam, t_in, t_out,
                          rows, *, tile_xy=None, tile_keep: float = 1.0,
                          epoch: int | None = None) -> SpatioTemporalModel:
    """Row-targeted re-profile (§6 at 130-camera scale): recompute ONLY the
    drifted source-camera ``rows`` from a fresh visit window and carry every
    other row of ``old`` bit-for-bit.

    Every per-pair statistic is row-local in the source camera (see
    ``correlation.ROW_LOCAL_FIELDS``): counts/hist/f0 accumulate per
    (src, dst) transition and the S/exit_frac normalizer is the row's own
    outbound total — so recomputing a row from the window is arithmetically
    identical to what a full ``build_model`` over the same window would put
    there, float-for-float (same accumulation, same float64 -> float32
    cast).  The one global field, ``entry``, is always recomputed from the
    FULL window.  Consequence (the property test's contract): when the
    non-drifted rows' window contents are unchanged, the merge is
    bit-identical to a full rebuild — at a fraction of the (C, C, NB) array
    traffic, which is the whole point at C=130.

    Tile masks: with ``tile_xy`` given and a tile-learned ``old``, the
    drifted rows' entry-region masks are re-learned from the window
    (restricted per-pair pass); without window positions the incumbent
    masks ride forward on every row, mirroring ``swap_model``'s carry.

    Shapes, n_bins and bin_width all come from ``old``, so the merged model
    hot-swaps without recompiling anything.  ``rows`` is deduplicated and
    sorted; ``epoch`` defaults to ``old.epoch`` (``engine.swap_model``
    restamps it on swap either way)."""
    from repro.core.correlation import splice_rows

    ent, cam, t_in, t_out = map(np.asarray, (ent, cam, t_in, t_out))
    rows = np.unique(np.asarray(rows, np.int64))
    C, NB, bw = old.n_cams, old.n_bins, old.bin_width
    if len(rows) == 0 or rows[0] < 0 or rows[-1] >= C:
        raise ValueError(f"merge_reprofiled_rows: rows {rows} outside the "
                         f"model's [0, {C}) camera range (or empty)")
    R = len(rows)
    row_of = np.full(C, -1, np.int64)
    row_of[rows] = np.arange(R)

    src, dst, dt, exit_cams, entry_cams = \
        transitions_from_visits(ent, cam, t_in, t_out)
    keep = row_of[src] >= 0
    r_src, r_dst, r_dt = row_of[src[keep]], dst[keep], dt[keep]

    counts = np.zeros((R, C), np.float64)
    np.add.at(counts, (r_src, r_dst), 1.0)
    hist = np.zeros((R, C, NB), np.float64)
    b = np.clip(r_dt // bw, 0, NB - 1)
    np.add.at(hist, (r_src, r_dst, b), 1.0)
    f0 = np.full((R, C), int(INF_TIME), np.int64)
    np.minimum.at(f0, (r_src, r_dst), r_dt)

    exits = np.zeros((R,), np.float64)
    keep_x = row_of[exit_cams] >= 0
    np.add.at(exits, row_of[exit_cams[keep_x]], 1.0)

    out_total = counts.sum(1) + exits
    denom = np.maximum(out_total, 1.0)
    S = counts / denom[:, None]
    exit_frac = exits / denom
    cdf = np.cumsum(hist, axis=-1)
    cdf = cdf / np.maximum(cdf[..., -1:], 1.0)

    entry = np.zeros((C,), np.float64)           # global: full window, always
    np.add.at(entry, entry_cams, 1.0)
    entry = entry / max(entry.sum(), 1.0)

    updates = dict(S=S, exit_frac=exit_frac, cdf=cdf,
                   f0=np.minimum(f0, int(INF_TIME)), counts=counts)
    if old.tile_admit is not None and tile_xy is not None \
            and old.tile_grid > 0:
        updates["tile_admit"] = tile_admit_from_visits(
            ent, cam, t_in, np.asarray(tile_xy), C, old.tile_grid,
            tile_keep, rows=rows)
    return splice_rows(old, rows, updates, entry=entry,
                       epoch=old.epoch if epoch is None else epoch)


def profiling_cost(ent, cam, t_in, t_out, sample_every: int = 1,
                   time_limit: int | None = None) -> int:
    """Frames the MTMC tracker must label for this profile (paper §8.4
    x-axis): one frame per camera per labeled tick in the profile window."""
    t_in = np.asarray(t_in)
    t_out = np.asarray(t_out)
    if time_limit is None:
        horizon = int(t_out.max()) + 1
    else:
        horizon = time_limit
    n_cams = int(np.asarray(cam).max()) + 1
    ticks = horizon // max(sample_every, 1)
    return int(ticks * n_cams)


def drift_score(model: SpatioTemporalModel, replay_rescues: np.ndarray,
                smoothing: float = 3.0) -> np.ndarray:
    """Paper §6 drift detection: rescue events per (c_s, c_d) normalized by the
    profile's transition counts (additively smoothed so single rescues on
    near-empty pairs don't dominate).  A spike (>> typical) triggers
    re-profiling of the corresponding camera pair.

    A fresh engine (no replays yet) has an all-zero rescue matrix: the score
    is exactly zero everywhere, returned without touching the division (so an
    unsmoothed call on a model with zero-count pairs never emits a
    divide-by-zero warning)."""
    rescues = np.asarray(replay_rescues, np.float64)
    if not rescues.any():
        return np.zeros_like(rescues)
    counts = np.asarray(model.counts, np.float64) + smoothing
    with np.errstate(divide="ignore", invalid="ignore"):
        score = rescues / counts
    # smoothing=0 on a never-profiled pair: a rescue there is infinite
    # surprise — keep it finite but dominant instead of propagating inf/nan
    return np.nan_to_num(score, nan=0.0, posinf=np.float64(1e18))
