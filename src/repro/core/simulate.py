"""Calibrated camera-network trajectory simulators (DESIGN.md §7).

DukeMTMC/Porto raw video is not distributable, so the paper's claims are
validated against simulators calibrated to its published statistics:

  duke_like_network   — 8 cameras; transition matrix built to match the
                        paper's Fig. 4 properties (≈1.9/7 peers receive >=5%
                        of outbound traffic; >50% of c7→c6 but <25% reverse;
                        c5 correlated with c2/c6 but not the nearer c7/c8),
                        travel times μ≈44.2s σ≈10.3s pooled (§3.1.2),
                        ~2700 identities / 85 min (§8.1).
  anoncampus_like     — 5 cameras on a hallway path graph, heavier occlusion
                        noise (indoor), 35 min (§8.1).
  porto_like_network  — 130 cameras on a road grid; taxis random-walk with
                        momentum; spatial locality emerges from the graph
                        (§8.1, Fig. 12/13).

One simulation step = 1 second.  The paper's frame counts are per-frame at
60/24 fps; all reported *ratios* (savings, recall, precision) are invariant
to the per-second aggregation, which we verify by also reporting fps-scaled
frame counts.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CameraNetwork:
    name: str
    n_cams: int
    trans: np.ndarray        # (C, C+1) row-stochastic next-camera probs; last col = exit
    travel_mean: np.ndarray  # (C, C) seconds
    travel_std: np.ndarray   # (C, C)
    entry: np.ndarray        # (C,) entry-camera distribution
    dwell_mean: float        # mean seconds an entity stays in one FOV
    geo_adjacent: np.ndarray  # (C, C) bool — the geo-proximity baseline's mask
    fps: int = 60            # native frame rate (for fps-scaled frame counts)


@dataclasses.dataclass
class Visits:
    """Detection log: one row per (entity, camera) visit."""
    ent: np.ndarray     # (V,) entity id
    cam: np.ndarray     # (V,) camera id
    t_in: np.ndarray    # (V,) first visible step
    t_out: np.ndarray   # (V,) last visible step (inclusive)
    horizon: int        # total simulated steps
    n_cams: int
    # normalized sub-frame detection position in [0, 1)^2, one per visit —
    # grid-agnostic, so one simulated world serves every tile_grid choice
    # (``tile_index`` quantizes at consumption time).  None = no spatial
    # labels (tile-granular admission degrades to whole-camera).
    tile_xy: np.ndarray | None = None   # (V, 2) float32 (x, y)

    def __len__(self):
        return len(self.ent)


def tile_index(tile_xy: np.ndarray, tile_grid: int) -> np.ndarray:
    """Quantize normalized (x, y) detection positions onto a T x T grid:
    flat tile id = floor(y*T)*T + floor(x*T), int32 in [0, T*T)."""
    xy = np.clip(np.asarray(tile_xy, np.float64), 0.0, np.nextafter(1.0, 0.0))
    tx = np.floor(xy[..., 0] * tile_grid).astype(np.int32)
    ty = np.floor(xy[..., 1] * tile_grid).astype(np.int32)
    return ty * np.int32(tile_grid) + tx


# ---------------------------------------------------------------------------
# network constructions
# ---------------------------------------------------------------------------

def duke_like_network() -> CameraNetwork:
    C = 8
    # Calibrated to paper Fig. 4's qualitative structure (see module docstring).
    T = np.array([
        #  c1     c2     c3     c4     c5     c6     c7     c8    exit
        [0.000, 0.510, 0.010, 0.005, 0.005, 0.005, 0.005, 0.160, 0.300],  # c1
        [0.350, 0.000, 0.330, 0.010, 0.010, 0.005, 0.005, 0.005, 0.285],  # c2
        [0.010, 0.360, 0.000, 0.280, 0.010, 0.005, 0.005, 0.005, 0.325],  # c3
        [0.005, 0.010, 0.330, 0.000, 0.300, 0.010, 0.005, 0.005, 0.335],  # c4
        [0.005, 0.300, 0.010, 0.015, 0.000, 0.330, 0.005, 0.005, 0.330],  # c5 -> 2,6 not 7,8
        [0.005, 0.010, 0.005, 0.010, 0.270, 0.000, 0.210, 0.015, 0.475],  # c6 -> 7 at 21% (<25%)
        [0.005, 0.005, 0.010, 0.005, 0.010, 0.560, 0.000, 0.085, 0.320],  # c7 -> 6 at 56% (>50%)
        [0.270, 0.010, 0.010, 0.005, 0.010, 0.015, 0.160, 0.000, 0.520],  # c8 -> 1,7; not 2,5
    ])
    assert np.allclose(T.sum(1), 1.0), T.sum(1)
    # Campus pedestrians wander: long tracks (many instances per identity, as
    # in DukeMTMC's 85-min footage) -> modest per-hop exit probability.
    exit_p = 0.12
    T[:, :C] *= (1.0 - exit_p) / T[:, :C].sum(1, keepdims=True)
    T[:, C] = exit_p
    rng = np.random.default_rng(7)
    # per-pair travel-time means spread around 44.2s, pooled sigma ~10.3s
    mean = np.clip(rng.normal(44.2, 8.0, (C, C)), 20.0, 75.0)
    std = np.clip(rng.normal(6.5, 1.5, (C, C)), 3.0, 10.0)
    # entries concentrate at the campus gates (cameras 1 and 8), as on the
    # real Duke deployment's perimeter cameras
    entry = np.array([0.42, 0.06, 0.04, 0.03, 0.05, 0.08, 0.06, 0.26])
    entry = entry / entry.sum()
    # geographic proximity baseline: ring-ish adjacency incl. the misleading
    # pairs the paper calls out (5-7, 5-8, 2-8 are geographically close).
    geo = np.zeros((C, C), bool)
    pairs = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (7, 0),
             (1, 4), (4, 6), (4, 7), (1, 7), (5, 7)]
    for a, b in pairs:
        geo[a, b] = geo[b, a] = True
    return CameraNetwork("duke-like", C, T, mean, std, entry,
                         dwell_mean=12.0, geo_adjacent=geo, fps=60)


def anoncampus_like_network() -> CameraNetwork:
    C = 5
    # hallway path: 1-2-3-4-5 with some skips (stairwells)
    T = np.array([
        [0.00, 0.52, 0.06, 0.02, 0.02, 0.38],
        [0.30, 0.00, 0.34, 0.04, 0.02, 0.30],
        [0.04, 0.32, 0.00, 0.30, 0.04, 0.30],
        [0.02, 0.04, 0.34, 0.00, 0.28, 0.32],
        [0.02, 0.02, 0.06, 0.44, 0.00, 0.46],
    ])
    assert np.allclose(T.sum(1), 1.0)
    exit_p = 0.18
    T[:, :C] *= (1.0 - exit_p) / T[:, :C].sum(1, keepdims=True)
    T[:, C] = exit_p
    rng = np.random.default_rng(11)
    mean = np.clip(rng.normal(18.0, 5.0, (C, C)), 8.0, 35.0)  # indoor: short walks
    std = np.clip(rng.normal(4.0, 1.0, (C, C)), 2.0, 7.0)
    entry = np.array([0.3, 0.15, 0.1, 0.15, 0.3])
    geo = np.zeros((C, C), bool)
    for a, b in [(0, 1), (1, 2), (2, 3), (3, 4)]:
        geo[a, b] = geo[b, a] = True
    return CameraNetwork("anoncampus-like", C, T, mean, std, entry,
                         dwell_mean=8.0, geo_adjacent=geo, fps=24)


def porto_like_network(n_cams: int = 130, grid=(13, 10), seed: int = 3) -> CameraNetwork:
    """Road-grid city: cameras at intersections, taxi-like momentum walks.

    The transition structure is derived from the grid adjacency: from each
    intersection, traffic continues straight with higher probability than it
    turns (momentum is approximated at the network level by non-uniform
    neighbor weights), and a fraction exits (trip ends)."""
    rows, cols = grid
    assert rows * cols >= n_cams
    rng = np.random.default_rng(seed)
    coords = np.array([(r, c) for r in range(rows) for c in range(cols)][:n_cams])
    C = n_cams
    T = np.zeros((C, C + 1))
    dist = np.abs(coords[:, None] - coords[None]).sum(-1)       # manhattan
    for i in range(C):
        nbrs = np.where(dist[i] == 1)[0]
        if len(nbrs) == 0:
            T[i, C] = 1.0
            continue
        w = rng.dirichlet(np.full(len(nbrs), 0.6)) * 0.75       # skewed main-road flow
        # a little long-range leakage (trips that skip an instrumented node)
        far = np.where(dist[i] == 2)[0]
        fw = np.zeros(0)
        if len(far):
            fw = rng.dirichlet(np.full(len(far), 0.4)) * 0.10
        exit_p = 1.0 - w.sum() - fw.sum()
        T[i, nbrs] = w
        if len(far):
            T[i, far] = fw
        T[i, C] = exit_p
    # block length ~300m at urban speeds ~20-40 km/h -> 30-55 s per hop
    base = rng.uniform(30.0, 55.0, (C, C))
    mean = base * np.maximum(dist, 1)
    std = np.clip(mean * 0.18, 2.0, 25.0)
    entry = rng.dirichlet(np.full(C, 2.0))
    geo = dist <= 4  # paper: geo-proximity threshold 4*l (l=100m)
    np.fill_diagonal(geo, False)
    return CameraNetwork(f"porto-like-{C}", C, T, mean, std, entry,
                         dwell_mean=6.0, geo_adjacent=geo, fps=1)


def clustered_city_network(n_cams: int = 130, n_clusters: int | None = None,
                           seed: int = 17) -> CameraNetwork:
    """Large synthetic deployment for the paper's 130-camera soak (§8.1):
    clusters of cameras (a neighborhood: one hub + leaves) joined by a
    corridor graph over the hubs (arterial roads).

    Structure, per cluster (cameras are CONTIGUOUS id blocks — cluster k owns
    ``[starts[k], starts[k+1])`` with the hub first — so localized drift
    injections can permute one block without touching the rest):

      * leaves feed the hub heavily and their ring neighbors lightly
        (local foot traffic),
      * the hub fans back out to its leaves and to corridor-adjacent hubs
        (a ring over clusters plus seeded chords),
      * intra-cluster hops are short (~8-20 s), corridor hops long
        (~30-70 s) — two clearly separated travel-time regimes, which is
        what makes the temporal windows discriminative at this scale,
      * entry mass concentrates at hubs (where traffic enters a
        neighborhood), ``geo_adjacent`` = cluster-mates + corridor pairs.

    Every draw comes from one ``default_rng(seed)`` in a fixed order, so the
    topology is bit-reproducible per (n_cams, n_clusters, seed) — the soak
    differential harness depends on that."""
    C = n_cams
    if n_clusters is None:
        # ~13-camera neighborhoods at C=130; at least 2 so a corridor exists
        n_clusters = max(2, int(round(np.sqrt(C / 1.3))))
    assert C >= 2 * n_clusters, \
        f"need >= 2 cameras per cluster: C={C}, n_clusters={n_clusters}"
    rng = np.random.default_rng(seed)
    sizes = np.full(n_clusters, C // n_clusters)
    sizes[: C % n_clusters] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)])
    members = [np.arange(starts[k], starts[k + 1]) for k in range(n_clusters)]
    hubs = np.array([int(m[0]) for m in members])

    # corridor graph over hubs: a ring plus ~K/2 chords
    corridor = {(k, (k + 1) % n_clusters) for k in range(n_clusters)}
    for _ in range(n_clusters // 2):
        a, b = rng.choice(n_clusters, 2, replace=False)
        corridor.add((min(a, b), max(a, b)))

    W = np.zeros((C, C))
    for k in range(n_clusters):
        hub, leaves = hubs[k], members[k][1:]
        n_leaf = len(leaves)
        for i, v in enumerate(leaves):
            W[v, hub] += 3.0                       # leaf -> hub: dominant
            if n_leaf > 1:                         # leaf ring: light local flow
                W[v, leaves[(i + 1) % n_leaf]] += 1.0
                W[v, leaves[(i - 1) % n_leaf]] += 1.0
            W[hub, v] += 1.0                       # hub fans back out
    for a, b in sorted(corridor):
        W[hubs[a], hubs[b]] += 2.5
        W[hubs[b], hubs[a]] += 2.5
    # per-edge seeded perturbation: no two pairs identically weighted
    W *= rng.uniform(0.7, 1.3, W.shape)
    np.fill_diagonal(W, 0.0)

    exit_p = 0.15
    row = W.sum(1)
    assert (row > 0).all()                          # every camera has an edge
    T = np.zeros((C, C + 1))
    T[:, :C] = W / row[:, None] * (1.0 - exit_p)
    T[:, C] = exit_p

    same_cluster = np.zeros((C, C), bool)
    for m in members:
        same_cluster[np.ix_(m, m)] = True
    mean = np.where(same_cluster, rng.uniform(8.0, 20.0, (C, C)),
                    rng.uniform(30.0, 70.0, (C, C)))
    std = np.clip(mean * 0.15, 1.5, 8.0)

    entry = np.full(C, 0.4 / C)                    # 60% of entries at hubs
    entry[hubs] += 0.6 / n_clusters
    entry = entry / entry.sum()

    geo = same_cluster.copy()
    for a, b in sorted(corridor):
        geo[hubs[a], hubs[b]] = geo[hubs[b], hubs[a]] = True
    np.fill_diagonal(geo, False)
    return CameraNetwork(f"city-{C}", C, T, mean, std, entry,
                         dwell_mean=10.0, geo_adjacent=geo, fps=1)


def permute_network(net: CameraNetwork, perm) -> CameraNetwork:
    """Traffic-pattern shift (paper §6's drift risk): relabel the topology by
    a camera permutation — camera i now behaves like camera ``perm[i]`` did
    (transitions, travel times, entry mass, geo adjacency all follow).  A
    derangement makes a model profiled on ``net`` wrong on essentially every
    pair, which is the drift injection ``drift_sweep`` uses."""
    perm = np.asarray(perm)
    C = net.n_cams
    assert sorted(perm.tolist()) == list(range(C)), perm
    T = np.zeros_like(net.trans)
    T[:, :C] = net.trans[np.ix_(perm, perm)]
    T[:, C] = net.trans[perm, C]
    return CameraNetwork(
        f"{net.name}-perm", C, T,
        net.travel_mean[np.ix_(perm, perm)],
        net.travel_std[np.ix_(perm, perm)],
        net.entry[perm], net.dwell_mean,
        net.geo_adjacent[np.ix_(perm, perm)], net.fps)


def concat_visits(a: Visits, b: Visits, t_offset: int) -> Visits:
    """One continuous detection stream: ``b`` replayed starting ``t_offset``
    steps into ``a``'s clock, entity ids relabeled disjoint.  The mid-run
    traffic-pattern shift for drift experiments: a = the old world, b = the
    shifted world from ``t_offset`` on."""
    assert a.n_cams == b.n_cams
    e_off = int(a.ent.max()) + 1 if len(a) else 0
    tiles = None
    if a.tile_xy is not None and b.tile_xy is not None:
        tiles = np.concatenate([a.tile_xy, b.tile_xy])
    return Visits(
        np.concatenate([a.ent, b.ent + e_off]),
        np.concatenate([a.cam, b.cam]),
        np.concatenate([a.t_in, b.t_in + t_offset]),
        np.concatenate([a.t_out, b.t_out + t_offset]),
        max(a.horizon, t_offset + b.horizon), a.n_cams, tiles)


def restrict_network(net: CameraNetwork, cams: np.ndarray) -> CameraNetwork:
    """Sub-network over a camera subset (paper Fig. 13 scaling study).
    Transitions to removed cameras become exits."""
    cams = np.asarray(cams)
    C = len(cams)
    T = np.zeros((C, C + 1))
    T[:, :C] = net.trans[np.ix_(cams, cams)]
    T[:, C] = 1.0 - T[:, :C].sum(1)
    entry = net.entry[cams]
    entry = entry / entry.sum()
    return CameraNetwork(
        f"{net.name}-sub{C}", C, T,
        net.travel_mean[np.ix_(cams, cams)], net.travel_std[np.ix_(cams, cams)],
        entry, net.dwell_mean, net.geo_adjacent[np.ix_(cams, cams)], net.fps)


# ---------------------------------------------------------------------------
# trajectory simulation
# ---------------------------------------------------------------------------

# entry portals are a property of the camera PAIR geometry, not of any one
# simulation run: the doorway c7 feeds into c6 through sits at the same spot
# in every video.  Centers are drawn per directed (src, dst) pair from a
# dedicated generator seeded by the pair itself, so every seed/world over the
# same network shares them (what lets a model profiled on one world admit
# correctly on another).
_PORTAL_SALT = 0x7E11E5


def _portal_center(src: int, dst: int) -> np.ndarray:
    """Deterministic sub-frame entry region center for the directed camera
    pair (src -> dst), in [0.1, 0.9)^2 (portals sit inside the frame)."""
    g = np.random.default_rng([src, dst, _PORTAL_SALT])
    return g.uniform(0.1, 0.9, 2)


# detections scatter around the portal center by this much (normalized frame
# units).  At tile_grid=8 a tile is 0.125 wide, so ~95% of detections land
# within one tile of the center — the profiler's 3x3 smoothing halo covers
# the tail.
_PORTAL_JITTER = 0.03


def simulate_network(net: CameraNetwork, n_entities: int, horizon: int,
                     seed: int = 0) -> Visits:
    """Sample entity trajectories through the network -> visit table.

    Each visit also carries a normalized sub-frame position ``tile_xy``:
    network entries appear anywhere (uniform), while cross-camera handoffs
    appear near the directed pair's entry portal — the stable spatial
    structure CrossRoI-style tile admission learns and exploits."""
    rng = np.random.default_rng(seed)
    # spatial labels are an overlay on the visit process, not part of it:
    # they draw from their OWN generator so adding tile_xy left every
    # pre-existing world (visit order, dwell, transitions) bit-identical
    rng_xy = np.random.default_rng([seed, _PORTAL_SALT])
    ents, cams, tins, touts, xys = [], [], [], [], []
    C = net.n_cams
    enter_times = rng.uniform(0, horizon * 0.95, n_entities).astype(np.int64)
    for e in range(n_entities):
        t = int(enter_times[e])
        c = int(rng.choice(C, p=net.entry))
        xy = rng_xy.uniform(0.0, 1.0, 2)       # network entry: anywhere
        while t < horizon:
            dwell = max(2, int(rng.exponential(net.dwell_mean)))
            t_out = min(t + dwell, horizon - 1)
            ents.append(e)
            cams.append(c)
            tins.append(t)
            touts.append(t_out)
            xys.append(xy)
            if t_out >= horizon - 1:
                break
            nxt = int(rng.choice(C + 1, p=net.trans[c]))
            if nxt == C:
                break  # exits the network
            travel = max(1, int(rng.normal(net.travel_mean[c, nxt],
                                           net.travel_std[c, nxt])))
            xy = np.clip(_portal_center(c, nxt)
                         + rng_xy.normal(0.0, _PORTAL_JITTER, 2),
                         0.0, np.nextafter(1.0, 0.0))
            t = t_out + travel
            c = nxt
    return Visits(np.array(ents), np.array(cams), np.array(tins),
                  np.array(touts), horizon, C,
                  np.asarray(xys, np.float32).reshape(len(ents), 2))


# ---------------------------------------------------------------------------
# dense gallery (what the inference plane would extract per frame)
# ---------------------------------------------------------------------------

def build_gallery(visits: Visits, max_slots: int = 24):
    """Dense per-(camera, step) table of visit ids: (C, T, K) int32, -1 empty.

    The tracker reads gallery[c, t] as "entities detected in camera c's frame
    at step t" — i.e. the object-detector output the re-id model ranks."""
    C, T, K = visits.n_cams, visits.horizon, max_slots
    gal = np.full((C, T, K), -1, np.int32)
    fill = np.zeros((C, T), np.int32)
    overflow = 0
    for vid in range(len(visits)):
        c = visits.cam[vid]
        for t in range(visits.t_in[vid], visits.t_out[vid] + 1):
            k = fill[c, t]
            if k < K:
                gal[c, t, k] = vid
                fill[c, t] = k + 1
            else:
                overflow += 1
    return gal, overflow
