"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000.  GQA, no-bias, parallel attn+FFN block, LayerNorm
[hf:CohereForAI/c4ai-command-r-v01 lineage].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    norm_type="layernorm",
    parallel_block=True,
    use_bias=False,
    tie_embeddings=True,   # command-r ties embeddings
    rope_theta=75_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="command-r-smoke",
    family="dense",
    num_layers=3,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    norm_type="layernorm",
    parallel_block=True,
    use_bias=False,
    tie_embeddings=True,
    rope_theta=10_000.0,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    remat=False,
)
