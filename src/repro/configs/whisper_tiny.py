"""whisper-tiny [audio] — 4L d_model=384 6H d_ff=1536 vocab=51865.
Enc-dec; conv frontend is a STUB (input_specs provides precomputed frame
embeddings) [arXiv:2212.04356].

Positional encoding note (DESIGN.md §7): the backbone uses RoPE in place of
whisper's learned absolute positions — the assignment specifies the
transformer backbone only, and RoPE extends cleanly to the 32k decode shapes.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    encoder_layers=4,
    encoder_seq=1500,
    cross_attn=True,
    use_bias=True,
    norm_type="layernorm",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    encoder_layers=2,
    encoder_seq=32,
    cross_attn=True,
    use_bias=True,
    norm_type="layernorm",
    rope_theta=10_000.0,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    remat=False,
)
