"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  Mamba2 blocks + shared attention block applied
every 6 layers [arXiv:2411.15242].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_version=2,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_version=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    attn_every=2,
    rope_theta=10_000.0,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    remat=False,
)
