"""deepseek-7b [dense] — 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400.  llama-arch [arXiv:2401.02954].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    rope_theta=10_000.0,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    remat=False,
)
