"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=48,
    vocab_size=512,
    num_experts=8,
    experts_per_token=2,
    rope_theta=10_000.0,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    remat=False,
)
