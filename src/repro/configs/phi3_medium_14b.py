"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352.  RoPE SwiGLU GQA [arXiv:2404.14219].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="phi3-smoke",
    family="dense",
    num_layers=3,
    d_model=80,
    num_heads=4,
    num_kv_heads=1,
    d_ff=192,
    vocab_size=512,
    rope_theta=10_000.0,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    remat=False,
)
