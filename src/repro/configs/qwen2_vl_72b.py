"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064.  M-RoPE, dynamic-resolution vision frontend (STUB: input_specs
provides precomputed patch embeddings + 3D position ids) [arXiv:2409.12191].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    use_bias=True,          # qwen2 uses qkv bias
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    vision_stub=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    use_bias=True,
    mrope=True,
    mrope_sections=(4, 2, 2),
    rope_theta=10_000.0,
    vision_stub=True,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    remat=False,
)
