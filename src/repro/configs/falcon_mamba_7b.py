"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free) vocab=65024, ssm_state=16.

Mamba1 architecture [arXiv:2410.05355].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    head_dim=1,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_version=1,
    tie_embeddings=True,   # falcon-mamba ties input/output embeddings
    rope_theta=0.0,
)

SMOKE_CONFIG = ModelConfig(
    name="falcon-mamba-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    head_dim=1,
    ssm_state=8,
    ssm_expand=2,
    ssm_conv=4,
    ssm_version=1,
    ssm_chunk=16,
    tie_embeddings=True,
    rope_theta=0.0,
    remat=False,
)
