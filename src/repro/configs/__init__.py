"""Architecture registry: the 10 assigned backbones + ReXCam scenario configs.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests (small layers/width, few experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "falcon_mamba_7b",
    "command_r_plus_104b",
    "deepseek_7b",
    "phi3_medium_14b",
    "yi_6b",
    "zamba2_2p7b",
    "qwen2_vl_72b",
    "phi3p5_moe_42b",
    "qwen3_moe_30b",
    "whisper_tiny",
]

# Accept dashed ids from the assignment table too.
_ALIASES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "command-r-plus-104b": "command_r_plus_104b",
    "deepseek-7b": "deepseek_7b",
    "phi3-medium-14b": "phi3_medium_14b",
    "yi-6b": "yi_6b",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe_42b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "whisper-tiny": "whisper_tiny",
}


def canonical(arch_id: str) -> str:
    return _ALIASES.get(arch_id, arch_id)


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    return mod.SMOKE_CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
