"""Beyond-paper performance toggles (EXPERIMENTS.md §Perf).

Each flag is one hillclimb iteration; the paper-faithful baseline is all-off.
Flags are read at trace time by the model/moe/steps code.

  causal_skip            balanced two-sided q-chunk schedule: removes the ~2x
                         masked-out attention FLOPs of blockwise causal attn.
  moe_tp_dispatch        shard MoE dispatch over the model axis: each TP rank
                         routes a distinct 1/TP slice of the token chunk, so
                         the EP all-to-all and the expert-output psum shrink
                         ~TP x (they were duplicated across TP ranks).
  parallel_fused_ar      command-r parallel block: sum attn+mlp partial
                         outputs BEFORE the sharding constraint -> one TP
                         all-reduce per layer instead of two.
  serve_params_replicated  decode/prefill: drop FSDP on parameters when the
                         TP shard fits HBM -> no per-token weight all-gather
                         (weight-stationary serving).
  serve_seq_sharded_kv   decode: shard the KV-cache sequence dim over the
                         model axis when KV heads are not TP-divisible
                         (replicated KV caches overflow HBM on 32k shapes).
  dense_pure_fsdp        dense train: ZeRO-3 over all 256/512 chips, no TP.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading


@dataclasses.dataclass(frozen=True)
class PerfFlags:
    causal_skip: bool = False
    moe_tp_dispatch: bool = False
    parallel_fused_ar: bool = False
    serve_params_replicated: bool = False
    serve_seq_sharded_kv: bool = False
    # pure-FSDP (ZeRO-3, no tensor parallelism) for DENSE training: at 1M
    # tokens/step the per-chip weight all-gather (param bytes) is far below
    # the per-chip activation all-reduce volume (tokens_loc x D x layers), so
    # communication drops ~2.7x on the 104B arch.  Dense/vlm train only.
    dense_pure_fsdp: bool = False
    # bf16 stored/gathered params with an fp32 master copy in the optimizer
    # state: halves every weight all-gather and weight HBM stream (the fp32
    # gathers dominate pure-FSDP training comms).
    bf16_params: bool = False
    # pad non-TP-divisible vocabs (whisper: 51865 -> 51872) so logits shard;
    # pad columns are -inf-masked (softmax/CE unchanged).
    pad_vocab: bool = False

    @classmethod
    def all_on(cls) -> "PerfFlags":
        # dense_pure_fsdp intentionally NOT in all_on: it is a per-cell
        # tradeoff (helps big-dense train, hurts small models' memory)
        return cls(causal_skip=True, moe_tp_dispatch=True,
                   parallel_fused_ar=True, serve_params_replicated=True,
                   serve_seq_sharded_kv=True, bf16_params=True,
                   pad_vocab=True)


class _Box(threading.local):
    def __init__(self):
        self.flags = PerfFlags()


_BOX = _Box()


def get_flags() -> PerfFlags:
    return _BOX.flags


def set_flags(flags: PerfFlags) -> None:
    _BOX.flags = flags


@contextlib.contextmanager
def perf_flags(flags: PerfFlags):
    prev = get_flags()
    set_flags(flags)
    try:
        yield
    finally:
        set_flags(prev)
