from repro.perf.flags import PerfFlags, get_flags, set_flags, perf_flags  # noqa: F401
