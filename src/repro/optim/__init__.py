from repro.optim.adamw import OptConfig, init_opt_state, adamw_update, lr_at  # noqa: F401
from repro.optim.compress import (  # noqa: F401
    quantize_int8, dequantize_int8, compressed_psum, CompressionState,
    init_compression_state,
)
