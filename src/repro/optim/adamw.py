"""AdamW with warmup+cosine schedule and global-norm clipping (no optax).

Optimizer state shards like the parameters (the specs are derived from the
same logical axes), so FSDP-sharded params get ZeRO-sharded moments for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def lr_at(step, cfg: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = cfg.lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, master_weights: bool = False):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    st = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master_weights:
        # fp32 master copy (PerfFlags.bf16_params): params themselves are
        # stored/gathered in bf16; updates apply to the master.
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    masters = state.get("master")
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, cfg)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 1:  # decay matrices/vectors, not scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    if masters is not None:
        flat_w = treedef.flatten_up_to(masters)
        out = [upd(w, g, m, v) for w, g, m, v in zip(flat_w, flat_g, flat_m, flat_v)]
        new_master = treedef.unflatten([o[0] for o in out])
        new_p = jax.tree.map(lambda w, p: w.astype(p.dtype), new_master, params)
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "master": new_master,
                       "step": step}, {"grad_norm": gnorm, "lr": lr}
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
