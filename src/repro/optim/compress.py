"""Int8 error-feedback gradient compression for cross-pod reduction.

Inter-pod links are the scarcest bandwidth on a multi-pod mesh; 1-byte
gradients with error feedback (residual carried into the next step) keep
convergence while cutting the pod-axis reduce volume 4x.  The intra-pod
reduce stays fp32.

``compressed_psum`` is written for use inside ``shard_map``: it quantizes,
all-gathers the int8 payload over the (small) pod axis, and accumulates in
fp32.  Error feedback state is per-leaf and shards like the gradient.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

CompressionState = Any  # pytree of residuals, same structure as grads


def init_compression_state(grads_template):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array):
    """Returns (q, scale, new_err).  g is reconstructed as deq(q) + err'."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    return q, scale, target - deq


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback int8 mean-reduce over ``axis_name`` (inside shard_map).

    Returns (g_reduced fp32, new_err)."""
    q, scale, new_err = compress_with_feedback(g, err)
    n = jax.lax.psum(1, axis_name)
    qs = jax.lax.all_gather(q, axis_name)          # (n, ...) int8 payload
    ss = jax.lax.all_gather(scale, axis_name)      # (n,)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim)
    return deq.sum(0) / n, new_err
