from repro.parallel.sharding import (  # noqa: F401
    AxisRules,
    SINGLE_POD_RULES,
    MULTI_POD_RULES,
    HOST_RULES,
    logical_to_spec,
    shard_params_specs,
    constrain,
    set_mesh_context,
    get_mesh_context,
    mesh_context,
)
