"""Logical-axis sharding rules for the production mesh.

Model code annotates parameters and activations with *logical* axis names
("batch", "heads", "mlp", "fsdp", ...).  A rule table maps each logical name to
zero or more *mesh* axes.  This indirection is what lets the same model code
lower onto the single-pod (data=16, model=16) mesh, the multi-pod
(pod=2, data=16, model=16) mesh, a tiny test mesh, or a single host device.

Rules follow the MaxText convention: the value of a rule is a tuple of mesh
axis names (sharded over their product) or () for replicated.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis names to mesh axis tuples."""

    rules: Mapping[str, tuple[str, ...]]

    def get(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        if logical not in self.rules:
            raise KeyError(f"unknown logical axis {logical!r}; known: {sorted(self.rules)}")
        return tuple(self.rules[logical])


# Single-pod production mesh: (data=16, model=16).
SINGLE_POD_RULES = AxisRules(
    rules={
        # data-parallel / stream-parallel batch dim
        "batch": ("data",),
        # ZeRO-3 / FSDP shard dim for parameters (largest non-tensor dim)
        "fsdp": ("data",),
        # tensor-parallel dims
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "embed": (),            # d_model replicated (activations)
        "embed_tensor": ("model",),  # optional: shard d_model of some params over model
        # MoE: experts over the data axis (EP), expert hidden over model (TP)
        "experts": ("data",),
        "expert_mlp": ("model",),
        # sequence axes
        "seq": (),
        "kv_seq": (),             # decode KV cache sequence dim (dense decode)
        "kv_seq_shard": ("data",),  # long-context: sequence-parallel KV
        "kv_seq_model": ("model",),  # serve: seq-sharded cache when KV heads
                                     # are not TP-divisible (PerfFlags)
        # ssm
        "ssm_state": (),
        "ssm_inner": ("model",),
        # scan-stacked layer dim — never sharded
        "layers": (),
        # replicated
        "none": (),
    }
)

# Multi-pod mesh: (pod=2, data=16, model=16).  batch/fsdp additionally shard
# over the pod axis; tensor parallelism stays intra-pod (ICI locality).
MULTI_POD_RULES = AxisRules(
    rules={
        **SINGLE_POD_RULES.rules,
        "batch": ("pod", "data"),
        "fsdp": ("pod", "data"),
        "experts": ("data",),       # keep expert all-to-all intra-pod
        "kv_seq_shard": ("data",),
    }
)

# Single-device rules (tests, examples): everything replicated.
HOST_RULES = AxisRules(rules={k: () for k in SINGLE_POD_RULES.rules})


def pure_fsdp_rules(rules: AxisRules) -> AxisRules:
    """ZeRO-3-only variant (PerfFlags.dense_pure_fsdp): batch and parameter
    shards span BOTH mesh axes; tensor-parallel axes collapse to replicated.
    Communication becomes per-layer weight all-gathers + gradient
    reduce-scatters — no per-token activation all-reduces."""
    base = dict(rules.rules)
    both = tuple(base["fsdp"]) + ("model",)
    return AxisRules({**base,
                      "batch": both, "fsdp": both,
                      "heads": (), "kv_heads": (), "mlp": (), "vocab": (),
                      "embed_tensor": (), "ssm_inner": (), "expert_mlp": ()})


def logical_to_spec(logical_axes: Sequence[str | None], rules: AxisRules) -> P:
    """Convert a tuple of logical axis names (one per tensor dim) to a PartitionSpec."""
    spec: list[Any] = []
    for name in logical_axes:
        mesh_axes = rules.get(name)
        if len(mesh_axes) == 0:
            spec.append(None)
        elif len(mesh_axes) == 1:
            spec.append(mesh_axes[0])
        else:
            spec.append(tuple(mesh_axes))
    # Trailing Nones are harmless; keep explicit length for readability.
    return P(*spec)


def shard_params_specs(logical_tree: Any, rules: AxisRules) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_spec(axes, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


# ---------------------------------------------------------------------------
# Mesh context: model code calls constrain(x, (...logical...)) and we resolve
# against the active (mesh, rules) pair.  Outside any context this is a no-op,
# which keeps single-device tests and examples trivially runnable.
# ---------------------------------------------------------------------------

class _MeshContext(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: AxisRules | None = None


_CTX = _MeshContext()


def set_mesh_context(mesh: Mesh | None, rules: AxisRules | None) -> None:
    _CTX.mesh = mesh
    _CTX.rules = rules


def get_mesh_context() -> tuple[Mesh | None, AxisRules | None]:
    return _CTX.mesh, _CTX.rules


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None, rules: AxisRules | None):
    prev = get_mesh_context()
    set_mesh_context(mesh, rules)
    try:
        yield
    finally:
        set_mesh_context(*prev)


def constrain(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """Apply a sharding constraint given logical axis names (no-op without a mesh)."""
    mesh, rules = get_mesh_context()
    if mesh is None or rules is None:
        return x
    spec = logical_to_spec(logical_axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[str | None]) -> NamedSharding:
    mesh, rules = get_mesh_context()
    assert mesh is not None and rules is not None, "named_sharding needs a mesh context"
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules))
