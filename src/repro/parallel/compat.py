"""Version-compat shims for jax APIs that moved between releases.

The repo targets the newest jax mesh/shard_map surface but must run on the
baked-in toolchain (jax 0.4.x), where ``jax.sharding.AxisType`` and
``jax.shard_map`` do not exist yet.  All mesh construction and shard_map
entry points go through these helpers so the version split lives in exactly
one module.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    _AXIS_TYPE = jax.sharding.AxisType
except AttributeError:  # jax 0.4.x: meshes are implicitly Auto
    _AXIS_TYPE = None


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        # check_vma was named check_rep before the API moved to jax.shard_map
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
