"""Fused gallery ranking: similarity GEMM + running top-k — Pallas kernel.

The paper's inference-time hot loop (Fig. 2): rank a gallery of detected
objects by feature distance to the query.  TPU adaptation (DESIGN.md §3):
the distance reduces to an inner-product GEMM on the MXU (features are
L2-normalized: d = 2 - 2*s), and the ranking keeps a (block_q, K) running
top-k in VMEM merged tile-by-tile across gallery blocks — the full (Q, G)
score matrix never reaches HBM.

Grid (nq, ng): gallery axis innermost, top-k state carried in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _reid_kernel(q_ref, g_ref, sv_ref, si_ref, val_scr, idx_scr, *,
                 k: int, block_g: int, ng: int):
    gi = pl.program_id(1)

    @pl.when(gi == 0)
    def _init():
        val_scr[...] = jnp.full_like(val_scr, NEG_INF)
        idx_scr[...] = jnp.full_like(idx_scr, -1)

    q = q_ref[...].astype(jnp.float32)                    # (block_q, D)
    g = g_ref[...].astype(jnp.float32)                    # (block_g, D)
    s = jax.lax.dot_general(q, g, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (block_q, block_g)
    base = gi * block_g
    cols = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # merge running top-k with this tile's scores
    merged_v = jnp.concatenate([val_scr[...], s], axis=1)
    merged_i = jnp.concatenate([idx_scr[...], cols], axis=1)
    top_v, pos = jax.lax.top_k(merged_v, k)
    top_i = jnp.take_along_axis(merged_i, pos, axis=1)
    val_scr[...] = top_v
    idx_scr[...] = top_i

    @pl.when(gi == ng - 1)
    def _finalize():
        sv_ref[...] = val_scr[...]
        si_ref[...] = idx_scr[...]


def reid_topk(queries, gallery, k: int, *, block_q: int = 128,
              block_g: int = 512, interpret: bool = False):
    """queries: (Q, D); gallery: (G, D) -> (scores (Q, k), idx (Q, k)).

    Scores are inner products, descending (for unit features,
    distance = 2 - 2*score).
    """
    Q, D = queries.shape
    G = gallery.shape[0]
    block_q = min(block_q, Q)
    block_g = min(block_g, G)
    assert Q % block_q == 0 and G % block_g == 0
    nq, ng = Q // block_q, G // block_g

    kernel = functools.partial(_reid_kernel, k=k, block_g=block_g, ng=ng)
    return pl.pallas_call(
        kernel,
        grid=(nq, ng),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_g, D), lambda qi, gi: (gi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, gallery)
