"""Fused gallery ranking: similarity GEMM + running top-k — Pallas kernel.

The paper's inference-time hot loop (Fig. 2): rank a gallery of detected
objects by feature distance to the query.  TPU adaptation (DESIGN.md §3):
the distance reduces to an inner-product GEMM on the MXU (features are
L2-normalized: d = 2 - 2*s), and the ranking keeps a (block_q, K) running
top-k in VMEM merged tile-by-tile across gallery blocks — the full (Q, G)
score matrix never reaches HBM.

Ragged shapes: real gallery sizes are whatever the admission filter lets
through, so both entry points pad Q/G up to block multiples internally and
mask the padding to NEG_INF inside the kernel (padded indices come back as
-1 in the returned top-k).

``reid_topk_masked`` is the serving-engine variant: one deduplicated
embedding batch per round, where query q may only score gallery row g when
``admit[q, gal_cam[g]]`` is set and ``gal_frame[g] == q_frame[q]`` — the
segment mask is enforced on-device (camera membership via a one-hot GEMM,
MXU-friendly; no (Q, G) mask ever materializes in HBM).

Grid (nq, ng): gallery axis innermost, top-k state carried in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_rows(a, n: int, fill):
    pad = n - a.shape[0]
    if pad <= 0:
        return a
    return jnp.concatenate(
        [a, jnp.full((pad,) + a.shape[1:], fill, a.dtype)], axis=0)


def _blocks(dim: int, block: int, align: int):
    """Shrink ``block`` to the (aligned) extent of a small axis, then round
    the axis up to a whole number of blocks."""
    block = min(block, _round_up(dim, align))
    return block, _round_up(dim, block)


def _merge_topk(s, cols, val_scr, idx_scr, k: int):
    """Fold one (block_q, block_g) score tile into the running VMEM top-k."""
    merged_v = jnp.concatenate([val_scr[...], s], axis=1)
    merged_i = jnp.concatenate([idx_scr[...], cols], axis=1)
    top_v, pos = jax.lax.top_k(merged_v, k)
    val_scr[...] = top_v
    idx_scr[...] = jnp.take_along_axis(merged_i, pos, axis=1)


def _reid_kernel(q_ref, g_ref, sv_ref, si_ref, val_scr, idx_scr, *,
                 k: int, block_g: int, ng: int, g_real: int):
    gi = pl.program_id(1)

    @pl.when(gi == 0)
    def _init():
        val_scr[...] = jnp.full_like(val_scr, NEG_INF)
        idx_scr[...] = jnp.full_like(idx_scr, -1)

    q = q_ref[...].astype(jnp.float32)                    # (block_q, D)
    g = g_ref[...].astype(jnp.float32)                    # (block_g, D)
    s = jax.lax.dot_general(q, g, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (block_q, block_g)
    base = gi * block_g
    cols = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols < g_real, s, NEG_INF)              # gallery padding
    _merge_topk(s, cols, val_scr, idx_scr, k)

    @pl.when(gi == ng - 1)
    def _finalize():
        sv_ref[...] = val_scr[...]
        si_ref[...] = idx_scr[...]


def _mask_padded(sv, si):
    """Padded / fully-masked slots surface as idx -1."""
    return sv, jnp.where(sv > NEG_INF / 2, si, -1)


def _empty(Q: int, k: int):
    return (jnp.full((Q, k), NEG_INF, jnp.float32),
            jnp.full((Q, k), -1, jnp.int32))


def reid_topk(queries, gallery, k: int, *, block_q: int = 128,
              block_g: int = 512, interpret: bool = False):
    """queries: (Q, D); gallery: (G, D) -> (scores (Q, k), idx (Q, k)).

    Scores are inner products, descending (for unit features,
    distance = 2 - 2*score).  Q and G may be any size: inputs are padded to
    block multiples internally and padded slots come back as (NEG_INF, -1).
    """
    Q, D = queries.shape
    G = gallery.shape[0]
    if Q == 0 or G == 0:
        return _empty(Q, k)
    block_q, Qp = _blocks(Q, block_q, 8)
    block_g, Gp = _blocks(G, block_g, 128)
    nq, ng = Qp // block_q, Gp // block_g

    kernel = functools.partial(_reid_kernel, k=k, block_g=block_g, ng=ng,
                               g_real=G)
    sv, si = pl.pallas_call(
        kernel,
        grid=(nq, ng),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_g, D), lambda qi, gi: (gi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(_pad_rows(queries, Qp, 0), _pad_rows(gallery, Gp, 0))
    return _mask_padded(sv[:Q], si[:Q])


def _reid_masked_kernel(q_ref, qf_ref, adm_ref, g_ref, gf_ref, oh_ref,
                        sv_ref, si_ref, val_scr, idx_scr, *,
                        k: int, block_g: int, ng: int, g_real: int):
    gi = pl.program_id(1)

    @pl.when(gi == 0)
    def _init():
        val_scr[...] = jnp.full_like(val_scr, NEG_INF)
        idx_scr[...] = jnp.full_like(idx_scr, -1)

    q = q_ref[...].astype(jnp.float32)                    # (block_q, D)
    g = g_ref[...].astype(jnp.float32)                    # (block_g, D)
    s = jax.lax.dot_general(q, g, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (block_q, block_g)
    # camera admission via one-hot GEMM: (block_q, C) @ (C, block_g) on the
    # MXU — avoids a lane-axis gather of admit[:, gal_cam]
    cam_ok = jax.lax.dot_general(
        adm_ref[...].astype(jnp.float32), oh_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32) > 0.5
    frame_ok = qf_ref[...] == gf_ref[...]                 # (block_q, block_g)
    base = gi * block_g
    cols = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cam_ok & frame_ok & (cols < g_real), s, NEG_INF)
    _merge_topk(s, cols, val_scr, idx_scr, k)

    @pl.when(gi == ng - 1)
    def _finalize():
        sv_ref[...] = val_scr[...]
        si_ref[...] = idx_scr[...]


def _segment_masked_call(queries, q_tag, admit, gallery, gal_cam, gal_tag,
                         k: int, block_q: int, block_g: int, interpret: bool):
    """Shared padded pallas_call behind the frame-masked and segment-ID
    entry points.  Query q scores gallery row g only when
    ``admit[q, gal_cam[g]]`` and ``gal_tag[g] == q_tag[q]`` — the tag is the
    content frame for ``reid_topk_masked`` and the round-scoped segment id
    for ``reid_topk_segments``; int equality is the same kernel either way.
    Padding keeps the tags disjoint (query side -1, gallery side -2) so a
    padded slot can never pair with anything real or padded."""
    Q, D = queries.shape
    G = gallery.shape[0]
    C = admit.shape[1]
    if Q == 0 or G == 0:
        return _empty(Q, k)
    block_q, Qp = _blocks(Q, block_q, 8)
    block_g, Gp = _blocks(G, block_g, 128)
    Cp = _round_up(C, 8)
    nq, ng = Qp // block_q, Gp // block_g

    queries = _pad_rows(queries, Qp, 0)
    q_tag = _pad_rows(jnp.asarray(q_tag, jnp.int32)[:, None], Qp, -1)
    admit = _pad_rows(admit.astype(jnp.float32), Qp, 0.0)
    admit = jnp.pad(admit, ((0, 0), (0, Cp - C)))
    gallery = _pad_rows(gallery, Gp, 0)
    gal_cam = _pad_rows(jnp.asarray(gal_cam, jnp.int32), Gp, -1)
    gal_tag = _pad_rows(jnp.asarray(gal_tag, jnp.int32), Gp, -2)[None, :]
    # (Cp, Gp) camera one-hot; padded rows (cam -1) match no camera
    onehot = (gal_cam[None, :] == jnp.arange(Cp)[:, None]).astype(jnp.float32)

    kernel = functools.partial(_reid_masked_kernel, k=k, block_g=block_g,
                               ng=ng, g_real=G)
    sv, si = pl.pallas_call(
        kernel,
        grid=(nq, ng),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, 1), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, Cp), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_g, D), lambda qi, gi: (gi, 0)),
            pl.BlockSpec((1, block_g), lambda qi, gi: (0, gi)),
            pl.BlockSpec((Cp, block_g), lambda qi, gi: (0, gi)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, q_tag, admit, gallery, gal_tag, onehot)
    return _mask_padded(sv[:Q], si[:Q])


def reid_topk_masked(queries, q_frame, admit, gallery, gal_cam, gal_frame,
                     k: int, *, block_q: int = 128, block_g: int = 512,
                     interpret: bool = False):
    """Segment-masked gallery ranking over one deduplicated embedding batch.

    queries (Q, D); q_frame (Q,) int32 — the content frame each query's
    cursor is on; admit (Q, C) bool — the admission mask; gallery (G, D);
    gal_cam / gal_frame (G,) int32 — which (camera, frame) each gallery row
    came from.  Query q scores row g only when ``admit[q, gal_cam[g]]`` and
    ``gal_frame[g] == q_frame[q]``; everything else is NEG_INF.  Returns
    (scores (Q, k), idx (Q, k)) with fully-masked slots as (NEG_INF, -1).
    """
    return _segment_masked_call(queries, q_frame, admit, gallery, gal_cam,
                                gal_frame, k, block_q, block_g, interpret)


def reid_topk_segments(queries, q_seg, admit, gallery, gal_cam, gal_seg,
                       k: int, *, block_q: int = 128, block_g: int = 512,
                       interpret: bool = False):
    """Consolidated-round ranking: frame tags replaced by round-scoped
    segment ids.

    The engine's consolidation plane relabels each round's distinct content
    frames to compact segment ids (an injective per-round map), tags every
    query (``q_seg``, (Q,) int32) and gallery row (``gal_seg``, (G,) int32)
    with its segment, and ranks ALL live queries in one call.  Because the
    relabeling is injective, ``gal_seg[g] == q_seg[q]`` holds exactly when
    the underlying frames agree — the masked score matrix, and therefore
    every flat-argmin tie-break, is bit-identical to per-frame
    ``reid_topk_masked``.  Returns (scores (Q, k), idx (Q, k)) with
    fully-masked slots as (NEG_INF, -1).
    """
    return _segment_masked_call(queries, q_seg, admit, gallery, gal_cam,
                                gal_seg, k, block_q, block_g, interpret)


def _reid_tiles_kernel(q_ref, qt_ref, adm_ref, g_ref, gt_ref, oh_ref,
                       live_ref, sv_ref, si_ref, val_scr, idx_scr, *,
                       k: int, block_g: int, ng: int, g_real: int):
    """The segment-masked kernel body over the fused (camera x tile) axis,
    with a per-(q-block, g-block) liveness predicate: when no query row of
    this block admits any (camera, tile) cell present in this gallery block,
    the GEMM + merge are skipped entirely.  Skipping is provably free: every
    score the skipped block would contribute is NEG_INF, and ``_merge_topk``
    resolves NEG_INF ties in favor of the existing scratch entries — the
    scratch is bit-identical either way."""
    gi = pl.program_id(1)

    @pl.when(gi == 0)
    def _init():
        val_scr[...] = jnp.full_like(val_scr, NEG_INF)
        idx_scr[...] = jnp.full_like(idx_scr, -1)

    @pl.when(live_ref[0, 0] > 0)
    def _score():
        q = q_ref[...].astype(jnp.float32)                # (block_q, D)
        g = g_ref[...].astype(jnp.float32)                # (block_g, D)
        s = jax.lax.dot_general(q, g, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # (cam, tile) admission via one-hot GEMM over the fused axis —
        # same MXU shape as camera admission, just C*T*T columns
        ct_ok = jax.lax.dot_general(
            adm_ref[...].astype(jnp.float32), oh_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32) > 0.5
        tag_ok = qt_ref[...] == gt_ref[...]               # (block_q, block_g)
        base = gi * block_g
        cols = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(ct_ok & tag_ok & (cols < g_real), s, NEG_INF)
        _merge_topk(s, cols, val_scr, idx_scr, k)

    @pl.when(gi == ng - 1)
    def _finalize():
        sv_ref[...] = val_scr[...]
        si_ref[...] = idx_scr[...]


def reid_topk_tiles(queries, q_tag, admit_ct, gallery, gal_ct, gal_tag,
                    k: int, *, block_q: int = 128, block_g: int = 512,
                    interpret: bool = False):
    """Tile-granular gallery ranking: camera admission refined to sub-frame
    (camera, tile) cells, structurally the segment-masked kernel over a
    bigger "camera" axis.

    queries (Q, D); q_tag (Q,) int32 round-scoped segment ids; admit_ct
    (Q, C*T*T) bool — ``admit_ct[q, c*T*T + t]`` fuses camera admission AND
    the learned tile-admit mask; gallery (G, D); gal_ct (G,) int32 — each
    row's fused cell id ``gal_cam*T*T + gal_tile`` (rows with no tile label
    may carry -1: they match nothing); gal_tag (G,) int32 segment ids.
    Eligibility = ``admit_ct[q, gal_ct[g]]`` AND ``gal_tag[g] == q_tag[q]``.

    With every tile admitted, ``admit_ct[q, gal_ct[g]] == admit[q, gal_cam[g]]``
    for all rows, so the masked score matrix — and therefore every
    flat-argmin tie-break and (NEG_INF, -1) sentinel — is bit-identical to
    ``reid_topk_segments``: the camera-granular path is this kernel's
    differential oracle.

    The grid additionally skips dead (q-block, g-block) pairs: a block
    liveness table (any admitted (cam, tile) cell of the q-block present in
    the g-block) gates the GEMM + top-k merge per block, so compute scales
    with the admitted tile area, not the gallery.  Returns
    (scores (Q, k), idx (Q, k)) with fully-masked slots as (NEG_INF, -1).
    """
    Q, D = queries.shape
    G = gallery.shape[0]
    CT = admit_ct.shape[1]
    if Q == 0 or G == 0:
        return _empty(Q, k)
    block_q, Qp = _blocks(Q, block_q, 8)
    block_g, Gp = _blocks(G, block_g, 128)
    CTp = _round_up(CT, 8)
    nq, ng = Qp // block_q, Gp // block_g

    queries = _pad_rows(queries, Qp, 0)
    q_tag = _pad_rows(jnp.asarray(q_tag, jnp.int32)[:, None], Qp, -1)
    admit_ct = _pad_rows(admit_ct.astype(jnp.float32), Qp, 0.0)
    admit_ct = jnp.pad(admit_ct, ((0, 0), (0, CTp - CT)))
    gallery = _pad_rows(gallery, Gp, 0)
    gal_ct = _pad_rows(jnp.asarray(gal_ct, jnp.int32), Gp, -1)
    gal_tag = _pad_rows(jnp.asarray(gal_tag, jnp.int32), Gp, -2)[None, :]
    # (CTp, Gp) fused-cell one-hot; unlabeled/padded rows (cell -1) match
    # no admission column
    onehot = (gal_ct[None, :] == jnp.arange(CTp)[:, None]).astype(jnp.float32)

    # block liveness: does ANY query row of q-block qi admit ANY fused cell
    # present in g-block gi?  (Q-block any) x (cell-in-g-block any) — a tiny
    # (nq, CTp) @ (CTp, ng) product computed once per call, outside the grid.
    q_any = (admit_ct.reshape(nq, block_q, CTp).max(axis=1) > 0.0)
    g_has = (onehot.reshape(CTp, ng, block_g).max(axis=2) > 0.0)
    block_live = jax.lax.dot_general(
        q_any.astype(jnp.float32), g_has.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) > 0.0
    block_live = block_live.astype(jnp.int32)             # (nq, ng)

    kernel = functools.partial(_reid_tiles_kernel, k=k, block_g=block_g,
                               ng=ng, g_real=G)
    sv, si = pl.pallas_call(
        kernel,
        grid=(nq, ng),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, 1), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, CTp), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_g, D), lambda qi, gi: (gi, 0)),
            pl.BlockSpec((1, block_g), lambda qi, gi: (0, gi)),
            pl.BlockSpec((CTp, block_g), lambda qi, gi: (0, gi)),
            pl.BlockSpec((1, 1), lambda qi, gi: (qi, gi)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, gi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, q_tag, admit_ct, gallery, gal_tag, onehot, block_live)
    return _mask_padded(sv[:Q], si[:Q])
