"""Pallas TPU kernels for the inference-plane hot spots (DESIGN.md §3).

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
validated in interpret mode against the pure-jnp oracle in ref.py; ops.py
holds the jit'd public wrappers (auto-interpret off-TPU).
"""
from repro.kernels.ops import (  # noqa: F401
    decode_attention, flash_attention, mamba_scan, reid_topk,
    reid_topk_masked,
)
