"""Decode attention (one token vs a long KV cache) — split-K Pallas kernel.

Grid (B, KV, nk): the kv-cache axis is tiled innermost; all G q-heads of a kv
head are processed together (one (G, hd) x (hd, block_k) MXU call per tile).
Valid-length masking comes from a scalar per batch row kept in SMEM.
This is the flash-decoding-style kernel the serving engine uses for
``decode_32k`` / ``long_500k`` shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    # whole tile beyond the valid prefix -> skip
    @pl.when(ki * block_k < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); caches: (B, KV, T, hd); length: (B,) -> (B, H, hd)."""
    B, H, hd = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_k = min(block_k, T)
    assert T % block_k == 0
    nk = T // block_k
    scale = hd ** -0.5
    qr = q.reshape(B, KV, G, hd)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qr, k_cache, v_cache)
    return out.reshape(B, H, hd)
