"""Jit'd public wrappers for the Pallas kernels.

Each op auto-selects interpret mode off-TPU (the kernels are TPU targets;
interpret=True executes the kernel body in Python on CPU so correctness is
validated everywhere).  ``ref.py`` holds the pure-jnp oracles used by the
per-kernel allclose test sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (re-exported for tests/benches)
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.reid_topk import reid_topk as _reid
from repro.kernels.reid_topk import reid_topk_masked as _reid_masked
from repro.kernels.reid_topk import reid_topk_segments as _reid_segments
from repro.kernels.reid_topk import reid_topk_tiles as _reid_tiles


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool | None = None):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 512,
                     interpret: bool | None = None):
    return _decode(q, k_cache, v_cache, length, block_k=block_k,
                   interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_g", "interpret"))
def reid_topk(queries, gallery, k: int, *, block_q: int = 128,
              block_g: int = 512, interpret: bool | None = None):
    return _reid(queries, gallery, k, block_q=block_q, block_g=block_g,
                 interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_g", "interpret"))
def reid_topk_masked(queries, q_frame, admit, gallery, gal_cam, gal_frame,
                     k: int, *, block_q: int = 128, block_g: int = 512,
                     interpret: bool | None = None):
    """Segment-masked gallery ranking (the serving engine's match path):
    query q only scores gallery rows whose camera it admits at its frame."""
    return _reid_masked(queries, q_frame, admit, gallery, gal_cam, gal_frame,
                        k, block_q=block_q, block_g=block_g,
                        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_g", "interpret"))
def reid_topk_segments(queries, q_seg, admit, gallery, gal_cam, gal_seg,
                       k: int, *, block_q: int = 128, block_g: int = 512,
                       interpret: bool | None = None):
    """Consolidated-round ranking: one call for ALL live queries, frame
    tags replaced by round-scoped segment ids (injective per-round map)."""
    return _reid_segments(queries, q_seg, admit, gallery, gal_cam, gal_seg,
                          k, block_q=block_q, block_g=block_g,
                          interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_g", "interpret"))
def reid_topk_tiles(queries, q_tag, admit_ct, gallery, gal_ct, gal_tag,
                    k: int, *, block_q: int = 128, block_g: int = 512,
                    interpret: bool | None = None):
    """Tile-granular consolidated ranking: camera admission refined to fused
    (camera, tile) cells; all-tiles-admitted is bit-identical to
    ``reid_topk_segments`` (the camera-granular differential oracle)."""
    return _reid_tiles(queries, q_tag, admit_ct, gallery, gal_ct, gal_tag,
                       k, block_q=block_q, block_g=block_g,
                       interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(u, dt, Bm, Cm, A, *, chunk: int = 128, block_d: int = 256,
               interpret: bool | None = None):
    return _mamba(u, dt, Bm, Cm, A, chunk=chunk, block_d=block_d,
                  interpret=_auto_interpret(interpret))
