"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B, H, S, hd); k/v: (B, KV, T, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqh,bkth->bkgqt", qr, k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bkth->bkgqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, length) -> jax.Array:
    """q: (B, H, hd); caches: (B, KV, T, hd); length: (B,) -> (B, H, hd)."""
    B, H, hd = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bkth->bkgt", qr, k_cache.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.arange(T)[None] < length[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bkth->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def reid_topk_ref(queries, gallery, k: int):
    """queries: (Q, D); gallery: (G, D) — returns (scores (Q, k), idx (Q, k)).

    Scores are inner products (for L2-normalized features, distance =
    2 - 2*score); top-k by score descending — the paper's re-id ranking
    (Fig. 2) over a frame gallery.
    """
    s = queries.astype(jnp.float32) @ gallery.astype(jnp.float32).T
    return jax.lax.top_k(s, k)


def reid_topk_masked_ref(queries, q_frame, admit, gallery, gal_cam,
                         gal_frame, k: int):
    """Oracle for the segment-masked engine variant: query q may only score
    gallery row g when ``admit[q, gal_cam[g]]`` and ``gal_frame[g] ==
    q_frame[q]``.  Fully-masked top-k slots come back as (NEG_INF, -1)."""
    s = queries.astype(jnp.float32) @ gallery.astype(jnp.float32).T
    gal_cam = jnp.asarray(gal_cam, jnp.int32)
    valid = admit[:, gal_cam] & \
        (jnp.asarray(gal_frame)[None, :] == jnp.asarray(q_frame)[:, None])
    sv, si = jax.lax.top_k(jnp.where(valid, s, NEG_INF), k)
    return sv, jnp.where(sv > NEG_INF / 2, si, -1)


def reid_topk_segments_ref(queries, q_seg, admit, gallery, gal_cam,
                           gal_seg, k: int):
    """Oracle for the consolidated segment-ID variant: query q may only
    score gallery row g when ``admit[q, gal_cam[g]]`` and ``gal_seg[g] ==
    q_seg[q]`` — identical math to ``reid_topk_masked_ref`` with the frame
    tags swapped for round-scoped segment ids."""
    s = queries.astype(jnp.float32) @ gallery.astype(jnp.float32).T
    gal_cam = jnp.asarray(gal_cam, jnp.int32)
    valid = admit[:, gal_cam] & \
        (jnp.asarray(gal_seg)[None, :] == jnp.asarray(q_seg)[:, None])
    sv, si = jax.lax.top_k(jnp.where(valid, s, NEG_INF), k)
    return sv, jnp.where(sv > NEG_INF / 2, si, -1)


def reid_topk_tiles_ref(queries, q_tag, admit_ct, gallery, gal_ct, gal_tag,
                        k: int):
    """Oracle for the tile-granular variant: query q may only score gallery
    row g when ``admit_ct[q, gal_ct[g]]`` (the fused (camera, tile) cell is
    admitted; unlabeled rows carry gal_ct = -1 and match nothing) and
    ``gal_tag[g] == q_tag[q]``.  Identical math to the segment oracle with
    the camera axis widened to C*T*T cells."""
    s = queries.astype(jnp.float32) @ gallery.astype(jnp.float32).T
    gal_ct = jnp.asarray(gal_ct, jnp.int32)
    valid = jnp.where(gal_ct >= 0, admit_ct[:, gal_ct], False) & \
        (jnp.asarray(gal_tag)[None, :] == jnp.asarray(q_tag)[:, None])
    sv, si = jax.lax.top_k(jnp.where(valid, s, NEG_INF), k)
    return sv, jnp.where(sv > NEG_INF / 2, si, -1)


def mamba_scan_ref(u, dt, Bm, Cm, A, h0):
    """Sequential (step-by-step) selective scan oracle.

    u/dt: (B, L, D); Bm/Cm: (B, L, N); A: (D, N); h0: (B, D, N).
    Returns (y (B, L, D), h_final).
    """
    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp
        da = jnp.exp(dt_t[..., None] * A)             # (B, D, N)
        h = da * h + (dt_t * u_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), h
