"""Flash attention (prefill) — pl.pallas_call with explicit VMEM BlockSpecs.

TPU-native blocking: grid (B, H, nq, nk) with the kv dimension innermost
(sequential on TPU), carrying the online-softmax state (m, l, acc) in VMEM
scratch across kv steps.  Causal tiles above the diagonal are skipped with
``pl.when`` (no compute issued — the kernel-level analogue of the model's
balanced-schedule optimization).  GQA is expressed in the k/v index_map
(kv head = q head // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: tile fully above the diagonal contributes nothing — skip it.
    diag_ok = (ki * block_k <= qi * block_q + block_q - 1) if causal else True

    @pl.when(diag_ok)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False) -> jax.Array:
    """q: (B, H, S, hd); k/v: (B, KV, T, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    nq, nk = S // block_q, T // block_k
    scale = hd ** -0.5

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
