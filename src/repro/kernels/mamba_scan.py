"""Chunked selective scan (Mamba1) — Pallas kernel.

TPU adaptation of the CUDA selective-scan: grid (B, nd, nc) with the chunk
axis innermost; the SSM state h (block_d, N) persists in VMEM scratch across
chunks.  Within a chunk the recurrence is evaluated with an O(log chunk)
associative doubling over VMEM-resident (chunk, block_d, N) tiles — the
(B, L, D, N) tensor never exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_scr, *,
                 chunk: int, block_d: int, n_state: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0].astype(jnp.float32)                     # (chunk, block_d)
    dt = dt_ref[0].astype(jnp.float32)
    bm = b_ref[0].astype(jnp.float32)                    # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)
    A = a_ref[...].astype(jnp.float32)                   # (block_d, N)

    da = jnp.exp(dt[:, :, None] * A[None])               # (chunk, bd, N)
    db = (dt * u)[:, :, None] * bm[:, None, :]           # (chunk, bd, N)

    # inclusive associative scan (Blelloch doubling) along the chunk axis:
    # (a, b) o (a', b') = (a*a', a'*b + b')
    a_acc, b_acc = da, db
    shift = 1
    while shift < chunk:
        a_prev = jnp.pad(a_acc, ((shift, 0), (0, 0), (0, 0)),
                         constant_values=1.0)[:chunk]
        b_prev = jnp.pad(b_acc, ((shift, 0), (0, 0), (0, 0)))[:chunk]
        b_acc = a_acc * b_prev + b_acc
        a_acc = a_acc * a_prev
        shift *= 2

    h = a_acc * h_scr[...][None] + b_acc                 # (chunk, bd, N)
    y = jnp.einsum("cdn,cn->cd", h, cm)
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = h[-1]


def mamba_scan(u, dt, Bm, Cm, A, *, chunk: int = 128, block_d: int = 256,
               interpret: bool = False):
    """u/dt: (B, L, D); Bm/Cm: (B, L, N); A: (D, N) -> y (B, L, D).

    State starts at zero (prefill semantics); the decode path's single-step
    update lives in the model code (it is O(1) and memory-bound).
    """
    B, L, D = u.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    block_d = min(block_d, D)
    assert L % chunk == 0 and D % block_d == 0
    nc, nd = L // chunk, D // block_d

    kernel = functools.partial(_scan_kernel, chunk=chunk, block_d=block_d,
                               n_state=N)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, L, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, Bm, Cm, A)
