"""Deterministic, resumable data pipelines.

``SyntheticLMStream`` emits token batches from a fixed random bigram process —
learnable structure (a model's loss drops measurably within a few hundred
steps) with zero external data.  The cursor is part of the checkpointable
state, so restart resumes mid-epoch on the exact batch; sharding follows the
(host, data-axis) layout: each host generates only its slice.

``FrameEmbedStream`` produces the stub modality frontends' outputs
(audio-frame / vision-patch embeddings) for the audio/vlm backbones.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8          # bigram out-degree (lower = more learnable)
    process_index: int = 0
    process_count: int = 1
    cursor: int = 0             # batches already emitted (checkpointable)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        # fixed bigram table: token t -> one of `branching` successors
        self._succ = rng.integers(0, V, size=(V, self.branching))
        assert self.global_batch % self.process_count == 0
        self.local_batch = self.global_batch // self.process_count

    def state_dict(self):
        return {"cursor": self.cursor, "seed": self.seed}

    def load_state_dict(self, st):
        self.cursor = int(st["cursor"])
        assert int(st["seed"]) == self.seed, "stream seed mismatch on resume"

    def next_batch(self) -> dict:
        """Returns {"tokens": (local_batch, seq_len) int32} for this host."""
        # Per-(cursor, process) generator: reproducible and order-independent
        # across hosts; the walk is vectorized over rows.
        rng = np.random.default_rng(self.seed * 1_000_003 + self.cursor * 131 +
                                    self.process_index * 17)
        B, S = self.local_batch, self.seq_len
        out = np.empty((B, S), np.int32)
        t = rng.integers(0, self.vocab_size, size=B)
        branch = rng.integers(0, self.branching, size=(B, S))
        for s in range(S):
            out[:, s] = t
            t = self._succ[t, branch[:, s]]
        self.cursor += 1
        return {"tokens": out}


@dataclasses.dataclass
class FrameEmbedStream:
    """Stub modality frontend: precomputed frame/patch embeddings."""
    n_frames: int
    d_model: int
    global_batch: int
    seed: int = 0
    process_index: int = 0
    process_count: int = 1
    cursor: int = 0

    def __post_init__(self):
        assert self.global_batch % self.process_count == 0
        self.local_batch = self.global_batch // self.process_count

    def next_batch(self) -> dict:
        rng = np.random.default_rng(self.seed + self.cursor * 977 + self.process_index)
        self.cursor += 1
        return {"frames": rng.standard_normal(
            (self.local_batch, self.n_frames, self.d_model)).astype(np.float32) * 0.2}
