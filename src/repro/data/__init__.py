from repro.data.pipeline import SyntheticLMStream, FrameEmbedStream  # noqa: F401
