"""Fault-tolerant checkpointing: atomic manifests, async writes, GC, resume.

Layout (one directory per step)::

    <root>/step_000100.tmp-<nonce>/   # written here first
    <root>/step_000100/               # atomic rename when complete
        manifest.json                 # treedef, shapes, dtypes, step
        leaf_00000.npy ...

Restart safety: a crash mid-write leaves only a ``.tmp-*`` directory, which
restore ignores and GC removes.  ``CheckpointManager`` adds async writing
(snapshot to host, write on a worker thread — the train loop never blocks on
disk) and keep-last-k retention.  On a multi-host cluster each process writes
``leaf_*.proc<k>.npy`` shards of its addressable data; this single-host build
writes fully-replicated leaves (process 0 semantics).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(root: str, step: int, tree: Any) -> str:
    """Synchronous atomic checkpoint write.  Returns the final directory."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten_with_paths(tree)
    host_leaves = jax.device_get(leaves)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(x)) for x in host_leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in host_leaves],
    }
    for i, leaf in enumerate(host_leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), np.asarray(leaf))
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)     # atomic publish
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(root: str, step: int | None = None) -> tuple[int, Any]:
    """Restores (step, pytree).  step=None -> latest complete checkpoint."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    treedef = _deserialize_treedef(manifest["treedef"])
    leaves = [np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
              for i in range(manifest["n_leaves"])]
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves)


def _deserialize_treedef(hexstr: str):
    from jax.tree_util import PyTreeDef, default_registry
    return PyTreeDef.deserialize_using_proto(default_registry, bytes.fromhex(hexstr))


def gc_checkpoints(root: str, keep: int = 3) -> list[int]:
    """Remove tmp litter and all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(root):
        return []
    removed = []
    steps = []
    for name in list(os.listdir(root)):
        p = os.path.join(root, name)
        if ".tmp-" in name:
            shutil.rmtree(p, ignore_errors=True)
            continue
        if name.startswith("step_"):
            steps.append(int(name.split("_")[1]))
    for s in sorted(steps)[:-keep] if keep else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)
        removed.append(s)
    return removed


class CheckpointManager:
    """Async checkpointing with retention — the train loop calls ``save`` and
    keeps stepping; the previous write is joined before a new one starts."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Any, blocking: bool = False):
        self.wait()
        # Snapshot on the caller thread (device_get) so the train loop can
        # donate/overwrite buffers immediately afterwards.
        leaves, treedef = jax.tree.flatten(tree)
        host = jax.device_get(leaves)
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            try:
                save_checkpoint(self.root, step, snapshot)
                gc_checkpoints(self.root, self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self):
        self.wait()
        return restore_checkpoint(self.root)
