"""Mixture-of-Experts FFN with expert-parallel (EP) dispatch.

Design (TPU-native, not a GShard one-hot-einsum port):
  * token-choice top-k routing (fp32 router),
  * sort-based capacity dispatch — tokens are scatter-packed into fixed
    ``(E, C)`` buffers via an argsort over expert ids (static shapes, no
    (T,E,C) one-hot tensors),
  * under a mesh, a ``shard_map`` over the ``data`` axis all-to-alls the
    packed buffers to the expert-owning devices (EP=|data|), runs the batched
    expert GEMMs with the hidden dim tensor-sharded over ``model`` (psum to
    combine), and all-to-alls results back,
  * without a mesh (smoke tests / examples) the identical dispatch math runs
    locally.

Dispatch is chunked over tokens (``moe_chunk``) so the packed buffers stay a
few hundred MB at the 1M-token production batch instead of multi-GB.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import Params, _dtype, _pdtype, dense_init
from repro.parallel.compat import shard_map
from repro.parallel.sharding import constrain, get_mesh_context

MOE_CHUNK = 8192          # tokens per dispatch chunk (per device)
MIN_CAPACITY = 4


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = _pdtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), d, dt),
        "wg": dense_init(ks[2], (e, d, f), d, dt),
        "wo": dense_init(ks[3], (e, f, d), f, dt),
    }
    ax = {
        "router": ("none", "none"),
        "wi": ("experts", "none", "expert_mlp"),
        "wg": ("experts", "none", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "none"),
    }
    return p, ax


def _route(tokens_f32, router_w, k: int):
    """tokens: (T, D) -> (probs (T,k), ids (T,k), aux_metrics)."""
    logits = tokens_f32 @ router_w                                  # (T, E)
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch): E * sum_e f_e * p_e
    f_e = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f_e = f_e / jnp.maximum(f_e.sum(), 1.0)
    p_e = probs.mean(0)
    aux = E * jnp.sum(f_e * p_e)
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return top_p, top_i, aux, zloss


def _dispatch_indices(ids: jax.Array, E: int, C: int):
    """ids: (T, k) expert assignments -> packed-buffer index per (t, j).

    Returns (dest (T*k,), valid (T*k,)) where dest in [0, E*C) addresses the
    packed (E, C) buffer, computed by a stable argsort over expert ids
    (slot = rank of the token within its expert).  Overflow beyond capacity C
    is dropped (valid=False), matching capacity-factor routing.
    """
    Tk = ids.size
    flat = ids.reshape(-1)
    order = jnp.argsort(flat, stable=True)                          # (Tk,)
    sorted_e = flat[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")  # (E,)
    slot = jnp.arange(Tk) - start[sorted_e]
    valid_sorted = slot < C
    dest_sorted = jnp.where(valid_sorted, sorted_e * C + jnp.minimum(slot, C - 1), E * C)
    inv = jnp.argsort(order, stable=True)
    return dest_sorted[inv], (dest_sorted != E * C)[inv]


def _expert_ffn(xb: jax.Array, wi, wg, wo, dt):
    """xb: (E_l, M, D); weights (E_l, D, F_l)/(E_l, F_l, D) -> (E_l, M, D)."""
    h = jnp.einsum("emd,edf->emf", xb.astype(dt), wg.astype(dt))
    u = jnp.einsum("emd,edf->emf", xb.astype(dt), wi.astype(dt))
    h = jax.nn.silu(h) * u
    return jnp.einsum("emf,efd->emd", h, wo.astype(dt))


def _moe_chunk_local(tokens, router_w, wi, wg, wo, cfg: ModelConfig, C: int):
    """Single-device dispatch + expert compute for one token chunk."""
    T, D = tokens.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    dt = _dtype(cfg)
    top_p, top_i, aux, zloss = _route(tokens.astype(jnp.float32), router_w, k)
    dest, valid = _dispatch_indices(top_i, E, C)
    src = jnp.repeat(tokens, k, axis=0)                             # (T*k, D)
    buf = jnp.zeros((E * C + 1, D), tokens.dtype).at[jnp.where(valid, dest, E * C)].set(src)
    xb = buf[:E * C].reshape(E, C, D)
    yb = _expert_ffn(xb, wi, wg, wo, dt).reshape(E * C, D)
    y = yb[dest] * valid[:, None]                                   # (T*k, D)
    y = y.reshape(T, k, D) * top_p[..., None].astype(y.dtype)
    return y.sum(1), aux, zloss


def _moe_chunk_ep(tokens, router_w, wi, wg, wo, cfg: ModelConfig, C: int,
                  data_axis: str, model_axis: str | None, n_data: int):
    """shard_map body: tokens (T_l, D) local; wi/wg/wo local expert shards.

    With ``model_axis=None`` the expert weights are full-F (pre-gathered) and
    no TP psum is emitted."""
    T, D = tokens.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    E_l = E // n_data
    dt = _dtype(cfg)
    top_p, top_i, aux, zloss = _route(tokens.astype(jnp.float32), router_w, k)
    dest, valid = _dispatch_indices(top_i, E, C)
    src = jnp.repeat(tokens, k, axis=0)
    buf = jnp.zeros((E * C + 1, D), tokens.dtype).at[jnp.where(valid, dest, E * C)].set(src)
    send = buf[:E * C].reshape(n_data, E_l, C, D)
    # EP all-to-all: expert e = d*E_l + e_l lives on data-device d.
    recv = jax.lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0, tiled=False)
    xb = recv.transpose(1, 0, 2, 3).reshape(E_l, n_data * C, D)
    yb = _expert_ffn(xb, wi, wg, wo, dt)
    if model_axis is not None:
        yb = jax.lax.psum(yb, model_axis)                           # TP combine over F shards
    send_back = yb.reshape(E_l, n_data, C, D).transpose(1, 0, 2, 3)
    got = jax.lax.all_to_all(send_back, data_axis, split_axis=0, concat_axis=0, tiled=False)
    yflat = got.reshape(E * C, D)
    y = yflat[dest] * valid[:, None]
    y = y.reshape(T, k, D) * top_p[..., None].astype(y.dtype)
    return y.sum(1), aux, zloss


def moe_block(p: Params, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss, z_loss). Mesh-aware."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    mesh, rules = get_mesh_context()
    router_w = p["router"].astype(jnp.float32)

    use_ep = False
    data_axes: tuple[str, ...] = ()
    if mesh is not None and rules is not None:
        data_axes = rules.get("experts")
        use_ep = len(data_axes) == 1 and mesh.shape[data_axes[0]] > 1 and \
            E % mesh.shape[data_axes[0]] == 0

    if not use_ep:
        tokens = x.reshape(B * S, D)
        T = tokens.shape[0]
        chunk = min(MOE_CHUNK, T)
        C = max(MIN_CAPACITY, int(np.ceil(chunk * k / E * cfg.capacity_factor)))
        if T % chunk != 0:  # pad to a chunk multiple (decode tails)
            pad = chunk - T % chunk
            tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
        nch = tokens.shape[0] // chunk

        def step(_, tc):
            y, aux, zl = _moe_chunk_local(tc, router_w, p["wi"], p["wg"], p["wo"], cfg, C)
            return None, (y, aux, zl)

        _, (ys, auxs, zls) = jax.lax.scan(step, None, tokens.reshape(nch, chunk, D))
        out = ys.reshape(-1, D)[:T].reshape(B, S, D)
        return constrain(out, ("batch", "seq", "embed")), auxs.mean(), zls.mean()

    # ---- EP path under a mesh ----
    data_axis = data_axes[0]
    n_data = mesh.shape[data_axis]
    model_axes = rules.get("expert_mlp")
    model_axis = model_axes[0] if model_axes else None
    batch_axes = rules.get("batch")

    # per-device token count after batch sharding
    n_batch_shards = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    T_local = (B * S) // n_batch_shards
    chunk = min(MOE_CHUNK, T_local)
    C = max(MIN_CAPACITY, int(np.ceil(chunk * k / E * cfg.capacity_factor)))

    from jax.sharding import PartitionSpec as P

    tok_spec = P(tuple(batch_axes) if batch_axes else None, None)
    w_e_spec = P(data_axis, None, model_axis)
    wo_spec = P(data_axis, model_axis, None)

    from repro.perf import get_flags
    flags = get_flags()
    n_model = mesh.shape[model_axis] if model_axis else 1
    tp_dispatch = bool(flags.moe_tp_dispatch and model_axis and n_model > 1
                       and chunk % n_model == 0)

    # TP-sharded dispatch (PerfFlags.moe_tp_dispatch): each model rank routes
    # a distinct 1/TP slice of the chunk, so the EP all-to-all payload and the
    # expert GEMM shrink TP x (they are otherwise duplicated across TP ranks).
    # Expert weights are all-gathered over the model axis once per layer (in
    # bf16) so each rank computes full-F outputs for its tokens — no TP psum.
    C_eff = C if not tp_dispatch else max(
        MIN_CAPACITY, int(np.ceil(chunk / n_model * k / E * cfg.capacity_factor)))

    def body(tokens, rw, wi, wg, wo):
        Tl = tokens.shape[0]
        ch = min(chunk, Tl)
        pad = (-Tl) % ch
        tpad = jnp.pad(tokens, ((0, pad), (0, 0))) if pad else tokens
        nch = tpad.shape[0] // ch
        dt = _dtype(cfg)

        if tp_dispatch:
            wi_f = jax.lax.all_gather(wi.astype(dt), model_axis, axis=2, tiled=True)
            wg_f = jax.lax.all_gather(wg.astype(dt), model_axis, axis=2, tiled=True)
            wo_f = jax.lax.all_gather(wo.astype(dt), model_axis, axis=1, tiled=True)

        def step(_, tc):
            if tp_dispatch:
                my = jax.lax.axis_index(model_axis)
                sl = ch // n_model
                tc_slice = jax.lax.dynamic_slice_in_dim(tc, my * sl, sl, 0)
                y, aux, zl = _moe_chunk_ep(tc_slice, rw, wi_f, wg_f, wo_f, cfg,
                                           C_eff, data_axis, None, n_data)
                y = jax.lax.all_gather(y, model_axis, axis=0, tiled=True)
                return None, (y, aux, zl)
            return None, _moe_chunk_ep(tc, rw, wi, wg, wo, cfg, C_eff,
                                       data_axis, model_axis, n_data)

        _, (ys, auxs, zls) = jax.lax.scan(step, None, tpad.reshape(nch, ch, -1))
        y = ys.reshape(-1, tokens.shape[-1])[:Tl]
        aux = jax.lax.pmean(auxs.mean(), data_axis)
        zl = jax.lax.pmean(zls.mean(), data_axis)
        return y, aux, zl

    tokens = x.reshape(B * S, D)
    y, aux, zl = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(), w_e_spec, w_e_spec, wo_spec),
        out_specs=(tok_spec, P(), P()),
        check_vma=False,
    )(tokens, router_w, p["wi"], p["wg"], p["wo"])
    out = y.reshape(B, S, D)
    return constrain(out, ("batch", "seq", "embed")), aux, zl
