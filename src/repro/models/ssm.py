"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

TPU adaptation: the CUDA selective-scan kernel is recast as a *chunked* scan —
``lax.scan`` over chunks with an intra-chunk associative scan (mamba1) or the
matmul-form SSD recurrence (mamba2).  The ``(B, L, d_inner, N)`` tensor is
never materialized in HBM; peak live memory is one chunk.  The decode path is
the O(1)-state single-step update.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, _dtype, _pdtype, dense_init
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# depthwise causal conv1d
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None) -> jax.Array:
    """x: (B, L, C); w: (C, W) depthwise; returns (B, L, C)."""
    W = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    # sum of shifted slices — W is tiny (4), unrolled adds beat a conv op here.
    out = jnp.zeros_like(x, dtype=jnp.float32)
    L = x.shape[1]
    for i in range(W):
        out = out + xp[:, i:i + L].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  x_t: (B, C); conv_state: (B, W-1, C)."""
    W = w.shape[-1]
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,cw->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x_t.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg: ModelConfig):
    d, di, n, dtr, cw = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    dt = _pdtype(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": dense_init(ks[0], (d, 2 * di), d, dt),
        "conv_w": dense_init(ks[1], (di, cw), cw, dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n), di, dt),
        "dt_proj": dense_init(ks[3], (dtr, di), dtr, dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))).astype(dt),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))).astype(dt),
        "D": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[4], (di, d), di, dt),
    }
    ax = {
        "in_proj": ("fsdp", "ssm_inner"),
        "conv_w": ("ssm_inner", "none"),
        "conv_b": ("ssm_inner",),
        "x_proj": ("ssm_inner", "none"),
        "dt_proj": ("none", "ssm_inner"),
        "dt_bias": ("ssm_inner",),
        "A_log": ("ssm_inner", "ssm_state"),
        "D": ("ssm_inner",),
        "out_proj": ("ssm_inner", "fsdp"),
    }
    return p, ax


def _mamba1_scan_chunked(u, dt, Bm, Cm, A, h0, chunk: int):
    """u/dt: (B,L,di); Bm/Cm: (B,L,N); A: (di,N); h0: (B,di,N) fp32.

    Returns y: (B,L,di) fp32 and final state.
    """
    B, L, di = u.shape
    N = Bm.shape[-1]
    nc = L // chunk
    assert nc * chunk == L

    ur = u.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)
    dtr = dt.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)
    Br = Bm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Cr = Cm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        u_c, dt_c, B_c, C_c = inp              # (B,chunk,di), ..., (B,chunk,N)
        da = jnp.exp(dt_c[..., None] * A)       # (B,chunk,di,N) decay
        db = (dt_c * u_c)[..., None] * B_c[:, :, None, :]  # (B,chunk,di,N) input

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        ca, cb = jax.lax.associative_scan(combine, (da, db), axis=1)
        h_t = ca * h[:, None] + cb              # (B,chunk,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_t, C_c)
        return h_t[:, -1], y

    h_final, ys = jax.lax.scan(chunk_step, h0, (ur, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, di)
    return y, h_final


def mamba1_block(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: dict | None = None,
                 return_final_state: bool = False) -> tuple[jax.Array, dict | None]:
    """x: (B,L,D).  state: decode-mode {"h": (B,di,N), "conv": (B,W-1,di)}."""
    B, L, D = x.shape
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    dt_ = _dtype(cfg)
    xz = x.astype(dt_) @ p["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, ("batch", "seq", "ssm_inner"))

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)

    if state is None:
        xc = jax.nn.silu(causal_conv1d(xs, p["conv_w"], p["conv_b"]))
        proj = xc @ p["x_proj"].astype(dt_)
        dt_raw, Bm, Cm = jnp.split(proj, [dtr, dtr + n], axis=-1)
        dt = jax.nn.softplus(
            (dt_raw @ p["dt_proj"].astype(dt_)).astype(jnp.float32)
            + p["dt_bias"].astype(jnp.float32))
        h0 = jnp.zeros((B, di, n), jnp.float32)
        chunk = min(cfg.ssm_chunk, L)
        y, h_final = _mamba1_scan_chunked(xc.astype(jnp.float32), dt,
                                          Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                                          A, h0, chunk)
        y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
        new_state = None
        if return_final_state:
            # conv state = last (W-1) *pre-activation* conv inputs
            tail = xs[:, L - (cfg.ssm_conv - 1):, :]
            new_state = {"h": h_final, "conv": tail.astype(jnp.dtype(cfg.dtype))}
    else:
        xc_t, conv_state = conv1d_step(xs[:, 0], state["conv"], p["conv_w"], p["conv_b"])
        xc = jax.nn.silu(xc_t)
        proj = xc @ p["x_proj"].astype(dt_)
        dt_raw, Bm, Cm = jnp.split(proj, [dtr, dtr + n], axis=-1)
        dt = jax.nn.softplus(
            (dt_raw @ p["dt_proj"].astype(dt_)).astype(jnp.float32)
            + p["dt_bias"].astype(jnp.float32))                       # (B, di)
        da = jnp.exp(dt[..., None] * A)                               # (B,di,N)
        db = (dt * xc.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, None, :]
        h = da * state["h"] + db
        y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32))
        y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
        y = y[:, None]
        xc = xc[:, None]
        z = z
        new_state = {"h": h, "conv": conv_state}

    y = (y.astype(dt_) * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(dt_)
    return constrain(out, ("batch", "seq", "embed")), new_state


def mamba1_state_init(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.dtype(cfg.dtype)),
    }


def mamba1_state_axes():
    return {"h": ("batch", "ssm_inner", "ssm_state"), "conv": ("batch", None, "ssm_inner")}


# ---------------------------------------------------------------------------
# Mamba2 / SSD (zamba2)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, cw = cfg.ssm_num_heads, cfg.ssm_conv
    dt = _pdtype(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    p = {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + nh), d, dt),
        "conv_w": dense_init(ks[1], (conv_ch, cw), cw, dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))).astype(dt),
        "D": jnp.ones((nh,), dt),
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], (di, d), di, dt),
    }
    ax = {
        "in_proj": ("fsdp", "ssm_inner"),
        "conv_w": ("ssm_inner", "none"),
        "conv_b": ("ssm_inner",),
        "A_log": ("none",),
        "dt_bias": ("none",),
        "D": ("none",),
        "norm_scale": ("ssm_inner",),
        "out_proj": ("ssm_inner", "fsdp"),
    }
    return p, ax


def _ssd_chunked(x, dt, Bm, Cm, A, h0, chunk: int):
    """SSD matmul-form chunked scan.

    x: (B,L,nh,hd) fp32; dt: (B,L,nh); Bm/Cm: (B,L,N); A: (nh,) negative.
    h0: (B,nh,hd,N).  Returns y (B,L,nh,hd), h_final.
    """
    B, L, nh, hd = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    assert nc * chunk == L

    xr = x.reshape(B, nc, chunk, nh, hd).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(B, nc, chunk, nh).transpose(1, 0, 2, 3)
    Br = Bm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Cr = Cm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        x_c, dt_c, B_c, C_c = inp
        loga = dt_c * A                                    # (B,chunk,nh) <= 0
        cl = jnp.cumsum(loga, axis=1)                      # cumulative log decay
        # intra-chunk: seg[i,j] = exp(cl_i - cl_j) for i >= j
        seg = cl[:, :, None, :] - cl[:, None, :, :]        # (B,i,j,nh)
        ii = jnp.arange(chunk)
        causal = ii[:, None] >= ii[None, :]
        seg = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", C_c, B_c)          # (B,i,j)
        scores = cb[..., None] * seg                       # (B,i,j,nh)
        xdt = x_c * dt_c[..., None]                        # (B,chunk,nh,hd)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xdt)
        # inter-chunk contribution from carried state
        y_inter = jnp.einsum("bin,bhpn->bihp", C_c, h) * jnp.exp(cl)[..., None]
        # carry update
        w = jnp.exp(cl[:, -1:, :] - cl) * dt_c             # (B,chunk,nh)
        h_new = h * jnp.exp(cl[:, -1])[:, :, None, None] + \
            jnp.einsum("bjhp,bjn->bhpn", x_c * w[..., None], B_c)
        return h_new, y_intra + y_inter

    h_final, ys = jax.lax.scan(chunk_step, h0, (xr, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, L, nh, hd)
    return y, h_final


def mamba2_block(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: dict | None = None,
                 return_final_state: bool = False) -> tuple[jax.Array, dict | None]:
    B, L, D = x.shape
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    dt_ = _dtype(cfg)
    proj = x.astype(dt_) @ p["in_proj"].astype(dt_)
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xBC = constrain(xBC, ("batch", "seq", "ssm_inner"))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (nh,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if state is None:
        pre_conv = xBC
        xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
        xs, Bm, Cm = jnp.split(xBC, [di, di + n], axis=-1)
        xh = xs.astype(jnp.float32).reshape(B, L, nh, hd)
        h0 = jnp.zeros((B, nh, hd, n), jnp.float32)
        chunk = min(cfg.ssm_chunk, L)
        y, h_final = _ssd_chunked(xh, dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                                  A, h0, chunk)
        y = y + xh * p["D"].astype(jnp.float32)[:, None]
        y = y.reshape(B, L, di)
        new_state = None
        if return_final_state:
            tail = pre_conv[:, L - (cfg.ssm_conv - 1):, :]
            new_state = {"h": h_final, "conv": tail.astype(jnp.dtype(cfg.dtype))}
    else:
        xBC_t, conv_state = conv1d_step(xBC[:, 0], state["conv"], p["conv_w"], p["conv_b"])
        xBC_t = jax.nn.silu(xBC_t)
        xs, Bm, Cm = jnp.split(xBC_t, [di, di + n], axis=-1)
        xh = xs.astype(jnp.float32).reshape(B, nh, hd)
        dt1 = dt[:, 0]                                     # (B,nh)
        da = jnp.exp(dt1 * A)                              # (B,nh)
        h = state["h"] * da[:, :, None, None] + \
            jnp.einsum("bhp,bn->bhpn", xh * dt1[..., None], Bm.astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
        y = y + xh * p["D"].astype(jnp.float32)[:, None]
        y = y.reshape(B, 1, di)
        new_state = {"h": h, "conv": conv_state}

    # gated RMSNorm (mamba2 style) then out-projection
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"].astype(jnp.float32)
    out = y.astype(dt_) @ p["out_proj"].astype(dt_)
    return constrain(out, ("batch", "seq", "embed")), new_state


def mamba2_state_init(cfg: ModelConfig, batch: int):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "h": jnp.zeros((batch, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.dtype(cfg.dtype)),
    }


def mamba2_state_axes():
    return {"h": ("batch", "ssm_inner", None, "ssm_state"), "conv": ("batch", None, "ssm_inner")}
