"""Attention: GQA projections + blockwise (memory-bounded) prefill + decode.

Sharding-robust layout (DESIGN.md §5): every einsum operates on the FLAT
query-head dim H, which is zero-padded to a multiple of the tensor-parallel
degree (``cfg.num_padded_heads``) — the sharded dim is never reshaped, so
mesh-axis divisibility holds for all ten archs (phi3's 40 heads, whisper's 6
heads, ...).  K/V stay at their true KV-head count (replicated over the
model axis when KV % TP != 0) and are expanded to H heads chunk-by-chunk
inside the blockwise loops — the expansion never exceeds one chunk.

Pad heads are structurally inert: their q/k/v columns are zero-initialized
and the attention output is masked before the out-projection, so activations
AND gradients through the pads are exactly zero (numerically identical to
the published arch).

The pure-JAX blockwise path mirrors the Pallas flash-attention kernel's math
(online softmax over KV chunks).  ``causal_skip`` enables the balanced
two-sided q-chunk pairing that removes the ~2x masked-out FLOPs of naive
blockwise causal attention (a beyond-paper perf optimization; EXPERIMENTS.md
§Perf).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import Params, _dtype, _pdtype, apply_rope, apply_mrope, dense_init
from repro.parallel.sharding import constrain

NEG_INF = -1e30


def kv_map(cfg: ModelConfig) -> np.ndarray:
    """(H_pad,) static map: query head -> kv head (pads map to kv 0)."""
    G = cfg.num_heads // cfg.num_kv_heads
    m = np.arange(cfg.num_padded_heads) // G
    return np.where(np.arange(cfg.num_padded_heads) < cfg.num_heads, m, 0).astype(np.int32)


def head_mask(cfg: ModelConfig) -> np.ndarray | None:
    if cfg.num_padded_heads == cfg.num_heads:
        return None
    return (np.arange(cfg.num_padded_heads) < cfg.num_heads).astype(np.float32)


def init_attention(key, cfg: ModelConfig):
    d, hp, kv, hd = cfg.d_model, cfg.num_padded_heads, cfg.num_kv_heads, cfg.head_dim
    h = cfg.num_heads
    dt = _pdtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wq = dense_init(k1, (d, hp * hd), d, dt)
    wo = dense_init(k4, (hp * hd, d), h * hd, dt)
    if hp != h:  # zero the pad-head slices (structurally inert)
        mask = jnp.repeat(jnp.asarray(head_mask(cfg)), hd)
        wq = wq * mask[None, :].astype(dt)
        wo = wo * mask[:, None].astype(dt)
    p = {
        "wq": wq,
        "wk": dense_init(k2, (d, kv * hd), d, dt),
        "wv": dense_init(k3, (d, kv * hd), d, dt),
        "wo": wo,
    }
    kv_ax = "kv_heads" if cfg.shard_kv_heads else "none"
    ax = {"wq": ("fsdp", "heads"), "wk": ("fsdp", kv_ax),
          "wv": ("fsdp", kv_ax), "wo": ("heads", "fsdp")}
    if cfg.use_bias:
        bq = jnp.zeros((hp * hd,), dt)
        p["bq"] = bq
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
        ax["bq"] = ("heads",)
        ax["bk"] = (kv_ax,)
        ax["bv"] = (kv_ax,)
    return p, ax


def qkv_project(p: Params, x: jax.Array, cfg: ModelConfig, positions):
    """x: (B,S,D) -> q:(B,S,Hp,hd), k,v:(B,S,KV,hd) with RoPE applied."""
    B, S, _ = x.shape
    dt = _dtype(cfg)
    x = x.astype(dt)
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = q.reshape(B, S, cfg.num_padded_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kv_ax = "kv_heads" if cfg.shard_kv_heads else None
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", kv_ax, None))
    v = constrain(v, ("batch", "seq", kv_ax, None))
    return q, k, v


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (odd seq lens like whisper's
    1500 encoder frames get 500-sized tiles instead of an assert)."""
    if n <= target:
        return n
    for c in range(target, 0, -1):
        if n % c == 0:
            return c
    return n


def _expand_kv(k_c: jax.Array, kvm: jax.Array) -> jax.Array:
    """(B, C, KV, hd) -> (B, C, Hp, hd) via the static head map (one chunk)."""
    if k_c.shape[2] == kvm.shape[0]:  # MHA / already expanded: identity map
        return k_c
    return jnp.take(k_c, kvm, axis=2)


# ---------------------------------------------------------------------------
# blockwise attention (prefill / training)
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, cfg: ModelConfig, *, causal: bool = True,
                        causal_skip: bool = False) -> jax.Array:
    """Memory-bounded attention: scan over q chunks (outer) / kv chunks (inner).

    q: (B,S,Hp,hd), k/v: (B,T,KV,hd) -> (B,S,Hp,hd).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5
    Cq = _pick_chunk(S, cfg.attn_q_chunk)
    Ck = _pick_chunk(T, cfg.attn_kv_chunk)
    assert S % Cq == 0 and T % Ck == 0, (S, Cq, T, Ck)
    nq, nk = S // Cq, T // Ck
    kvm = jnp.asarray(kv_map(cfg))

    qr = q.reshape(B, nq, Cq, H, hd).transpose(1, 0, 2, 3, 4)      # (nq,B,Cq,H,hd)
    kr = k.reshape(B, nk, Ck, k.shape[2], hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, Ck, v.shape[2], hd).transpose(1, 0, 2, 3, 4)

    if causal and causal_skip and nq == nk and nq % 2 == 0:
        return _blockwise_causal_balanced(qr, kr, vr, cfg, scale, kvm,
                                          B, S, H, hd, Cq, Ck)

    def q_step(_, qi):
        q_c, iq = qi                              # (B,Cq,H,hd), chunk index

        def kv_step(carry, ki):
            m, l, acc = carry
            k_c, v_c, ik = ki
            kx = _expand_kv(k_c, kvm).astype(jnp.float32)          # (B,Ck,H,hd)
            vx = _expand_kv(v_c, kvm).astype(jnp.float32)
            s = jnp.einsum("bqhd,bchd->bhqc", q_c.astype(jnp.float32), kx,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = iq * Cq + jnp.arange(Cq)
                kpos = ik * Ck + jnp.arange(Ck)
                s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bhqc,bchd->bhqd", p, vx,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((B, H, Cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, Cq), jnp.float32)
        a0 = jnp.zeros((B, H, Cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kr, vr, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)               # (B,H,Cq,hd)
        return None, out.transpose(0, 2, 1, 3)                     # (B,Cq,H,hd)

    _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))     # (nq,B,Cq,H,hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def _blockwise_causal_balanced(qr, kr, vr, cfg, scale, kvm, B, S, H, hd, Cq, Ck):
    """Causal attention with two-sided q-chunk pairing (FLOP-balanced).

    Pairs q-chunk i with q-chunk n-1-i: together they need exactly n+1
    kv-tile visits, constant across pairs.  A 3-way ``lax.switch`` per kv
    step (both rows / hi row only / skip) keeps shapes static while issuing
    ~n(n+1)/2 tile visits total instead of n^2.
    """
    n = qr.shape[0]
    in_dtype = qr.dtype
    half = n // 2

    idx_lo = jnp.arange(half)
    idx_hi = n - 1 - idx_lo
    q_pair = jnp.stack([qr[idx_lo], qr[idx_hi]], axis=1)   # (half,2,B,Cq,H,hd)

    def pair_step(_, pi):
        q2, i = pi                                          # (2,B,Cq,H,hd)
        qpos2 = jnp.stack([i * Cq + jnp.arange(Cq),
                           (n - 1 - i) * Cq + jnp.arange(Cq)])   # (2, Cq)

        def tile(q_rows, kx, kpos, qpos_rows):
            s = jnp.einsum("rbqhd,bchd->rbhqc", q_rows.astype(jnp.float32), kx,
                           preferred_element_type=jnp.float32) * scale
            mask = qpos_rows[:, None, None, :, None] >= kpos[None, None, None, None, :]
            return jnp.where(mask, s, NEG_INF)

        def kv_step(carry, j):
            m, l, acc = carry                               # (2,B,H,Cq), ...
            kx = _expand_kv(kr[j], kvm).astype(jnp.float32)
            vx = _expand_kv(vr[j], kvm).astype(jnp.float32)
            kpos = j * Ck + jnp.arange(Ck)

            def both(op):
                m, l, acc = op
                s = tile(q2, kx, kpos, qpos2)
                m_new = jnp.maximum(m, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                pv = jnp.einsum("rbhqc,bchd->rbhqd", p, vx,
                                preferred_element_type=jnp.float32)
                return m_new, l * corr + p.sum(-1), acc * corr[..., None] + pv

            def hi_only(op):
                m, l, acc = op
                s1 = tile(q2[1:2], kx, kpos, qpos2[1:2])
                m1 = jnp.maximum(m[1:2], s1.max(-1))
                p1 = jnp.exp(s1 - m1[..., None])
                c1 = jnp.exp(m[1:2] - m1)
                pv1 = jnp.einsum("rbhqc,bchd->rbhqd", p1, vx,
                                 preferred_element_type=jnp.float32)
                return (jnp.concatenate([m[0:1], m1]),
                        jnp.concatenate([l[0:1], l[1:2] * c1 + p1.sum(-1)]),
                        jnp.concatenate([acc[0:1], acc[1:2] * c1[..., None] + pv1]))

            def skip(op):
                return op

            branch = jnp.where(j <= i, 0, jnp.where(j <= n - 1 - i, 1, 2))
            return jax.lax.switch(branch, (both, hi_only, skip), (m, l, acc)), None

        m0 = jnp.full((2, B, H, Cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((2, B, H, Cq), jnp.float32)
        a0 = jnp.zeros((2, B, H, Cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 1, 3, 2, 4)           # (2,B,Cq,H,hd)

    _, outs = jax.lax.scan(pair_step, None, (q_pair, idx_lo))
    out_lo = outs[:, 0]
    out_hi = outs[:, 1][::-1]
    out = jnp.concatenate([out_lo, out_hi], 0)              # (n,B,Cq,H,hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out.astype(in_dtype)


def full_attention(q, k, v, cfg: ModelConfig, *, causal: bool = True) -> jax.Array:
    """Reference O(S^2)-memory attention (small shapes / oracles only)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    kvm = jnp.asarray(kv_map(cfg))
    kx = _expand_kv(k, kvm).astype(jnp.float32)
    vx = _expand_kv(v, kvm).astype(jnp.float32)
    s = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32), kx) * hd ** -0.5
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p, vx)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (one new token against a KV cache) — chunked flash-decode
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, length, cfg: ModelConfig, *,
                     seq_shard=False, chunk: int = 4096) -> jax.Array:
    """q: (B,1,Hp,hd); k/v_cache: (B,T,KV,hd); length: (B,) valid prefix.

    Online-softmax scan over cache chunks: the expanded (B, chunk, Hp, hd)
    tile is the only transient.  With ``seq_shard`` the cache is
    sequence-sharded over the data axis (long_500k): the chunk axis keeps
    that sharding and XLA reduces the partial softmax stats across shards —
    flash-decoding expressed in SPMD.
    """
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    scale = hd ** -0.5
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    kvm = jnp.asarray(kv_map(cfg))
    q0 = q[:, 0].astype(jnp.float32)                        # (B,H,hd)

    if seq_shard:
        # Sequence-sharded cache: dense sharded-softmax path — scores stay
        # sharded on T, XLA reduces the softmax stats and the weighted sum
        # across the shards (flash-decoding in SPMD).
        #   "data"  (long_500k): batch=1 replicated, heads stay TP-sharded.
        #   "model" (serve_seq_sharded_kv): KV heads not TP-divisible — the
        #   model axis carries the sequence split, so q heads are gathered
        #   (replicated) for the score einsum and re-sharded afterwards.
        kx = _expand_kv(k_cache, kvm).astype(jnp.float32)
        vx = _expand_kv(v_cache, kvm).astype(jnp.float32)
        s = jnp.einsum("bhd,bthd->bht", q0, kx,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where((jnp.arange(T)[None] < length[:, None])[:, None], s, NEG_INF)
        if seq_shard == "model":
            s = constrain(s, ("batch", None, "kv_seq_model"))
        else:
            s = constrain(s, (None, "heads", "kv_seq_shard"))
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bht,bthd->bhd", p, vx, preferred_element_type=jnp.float32)
        out = out[:, None].astype(q.dtype)
        if seq_shard == "model":
            out = constrain(out, ("batch", "seq", "heads", None))
        return out

    kr = k_cache.reshape(B, nc, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vr = v_cache.reshape(B, nc, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    def kv_step(carry, ki):
        m, l, acc = carry
        k_c, v_c, ic = ki
        kx = _expand_kv(k_c, kvm).astype(jnp.float32)        # (B,chunk,H,hd)
        vx = _expand_kv(v_c, kvm).astype(jnp.float32)
        s = jnp.einsum("bhd,bchd->bhc", q0, kx,
                       preferred_element_type=jnp.float32) * scale
        pos = ic * chunk + jnp.arange(chunk)
        s = jnp.where((pos[None] < length[:, None])[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhc,bchd->bhd", p, vx, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv), None

    m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    a0 = jnp.zeros((B, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kr, vr, jnp.arange(nc)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out[:, None].astype(q.dtype)                      # (B,1,H,hd)


def attn_output(p: Params, o: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S = o.shape[:2]
    dt = _dtype(cfg)
    hm = head_mask(cfg)
    if hm is not None:  # keep pad heads inert in both value and gradient
        o = o * jnp.asarray(hm, o.dtype)[None, None, :, None]
    out = o.reshape(B, S, cfg.num_padded_heads * cfg.head_dim).astype(dt) @ p["wo"].astype(dt)
    return constrain(out, ("batch", "seq", "embed"))
