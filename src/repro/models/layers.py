"""Common neural-net building blocks (pure JAX, functional, dict params).

Every ``init_*`` returns ``(params, logical_axes)`` pytrees with identical
structure; logical axis names are resolved to mesh axes by
``repro.parallel.sharding``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype) -> jax.Array:
    """Truncated-normal fan-in init (maxtext-style scale 1/sqrt(fan_in))."""
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        p = {"scale": jnp.ones((d,), _pdtype(cfg)), "bias": jnp.zeros((d,), _pdtype(cfg))}
        ax = {"scale": ("none",), "bias": ("none",)}
    else:
        p = {"scale": jnp.ones((d,), _pdtype(cfg))}
        ax = {"scale": ("none",)}
    return p, ax


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, N, H); positions: (B, S) int32."""
    h = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(h, theta), jnp.float32)  # (h/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, h/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, N, H); positions3: (3, B, S) — (temporal, height, width) position
    streams.  The rotary half-dim is partitioned into three sections, each
    rotated by its own position stream (interleaved as in the HF reference).
    """
    h = x.shape[-1]
    half = h // 2
    sec = np.asarray(sections, np.int64)
    sec = (sec * half / sec.sum()).astype(np.int64)
    sec[2] = half - sec[0] - sec[1]
    freqs = jnp.asarray(rope_freqs(h, theta), jnp.float32)  # (half,)
    # Build per-frequency position source: section 0 uses temporal, 1 height, 2 width.
    src = np.concatenate([np.full(int(s), i, np.int32) for i, s in enumerate(sec)])
    pos = jnp.take(positions3, jnp.asarray(src), axis=0)           # (half, B, S)
    angles = jnp.transpose(pos, (1, 2, 0)).astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, gated: bool = True):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = _pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    if gated:
        p = {
            "wi": dense_init(k1, (d, f), d, dt),
            "wg": dense_init(k2, (d, f), d, dt),
            "wo": dense_init(k3, (f, d), f, dt),
        }
        ax = {"wi": ("fsdp", "mlp"), "wg": ("fsdp", "mlp"), "wo": ("mlp", "fsdp")}
    else:
        p = {
            "wi": dense_init(k1, (d, f), d, dt),
            "wo": dense_init(k3, (f, d), f, dt),
        }
        ax = {"wi": ("fsdp", "mlp"), "wo": ("mlp", "fsdp")}
    if cfg.use_bias:
        p["bi"] = jnp.zeros((f,), dt)
        p["bo"] = jnp.zeros((d,), dt)
        ax["bi"] = ("mlp",)
        ax["bo"] = ("none",)
    return p, ax


def apply_mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = _dtype(cfg)
    x = x.astype(dt)
    h = x @ p["wi"].astype(dt)
    if "bi" in p:
        h = h + p["bi"].astype(dt)
    if "wg" in p:
        g = x @ p["wg"].astype(dt)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("batch", "seq", "mlp"))
    o = h @ p["wo"].astype(dt)
    if "bo" in p:
        o = o + p["bo"].astype(dt)
    return o


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig):
    dt = _pdtype(cfg)
    k1, k2 = jax.random.split(key)
    v_ax = "vocab" if cfg.shard_vocab else "none"
    p = {"tok": embed_init(k1, (cfg.vocab_size, cfg.d_model), dt)}
    ax = {"tok": (v_ax, "fsdp")}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), cfg.d_model, dt)
        ax["head"] = ("fsdp", v_ax)
    return p, ax


def embed_tokens(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["tok"].astype(_dtype(cfg)), tokens, axis=0)
    return constrain(x, ("batch", "seq", "embed"))


def unembed(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["head"] if "head" in p else p["tok"].T
    logits = x.astype(_dtype(cfg)) @ w.astype(_dtype(cfg))
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.real_vocab_size:  # padded vocab: pad columns can never win
        pad_mask = jnp.arange(cfg.vocab_size) >= cfg.real_vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    v_ax = "vocab" if cfg.shard_vocab else None
    return constrain(logits, ("batch", "seq", v_ax))
