from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    init_params,
    param_logical_axes,
    forward,
    lm_loss,
    init_decode_state,
    decode_state_logical_axes,
    prefill,
    decode_step,
)
