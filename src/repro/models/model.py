"""Model assembly: init / forward / loss / prefill / decode for all families.

Families:
  dense  — llama-style GQA + SwiGLU (deepseek, yi, phi3, command-r parallel-block)
  vlm    — dense + M-RoPE (qwen2-vl); vision frontend is a stub (precomputed
           patch embeddings may be supplied via batch["embeds"])
  moe    — dense attention + expert-parallel MoE FFN (phi3.5-moe, qwen3-moe)
  ssm    — mamba1 stack (falcon-mamba)
  hybrid — mamba2 stack + one *shared* attention block applied every
           ``attn_every`` layers (zamba2)
  audio  — whisper-style enc-dec; conv frontend is a stub (precomputed frame
           embeddings supplied via batch["frames"])

Homogeneous layer stacks are parameter-stacked and driven by ``lax.scan``
(bounded compile time at 80 layers) with per-layer remat.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (
    Params, _dtype, apply_mlp, apply_norm, embed_tokens, init_embed, init_mlp,
    init_norm, unembed,
)
from repro.models.moe import init_moe, moe_block
from repro.parallel.sharding import constrain

LOSS_CHUNK = 2048


def _is_ax(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def _stack_axes(ax):
    return jax.tree.map(lambda a: ("layers",) + a, ax, is_leaf=_is_ax)


def _stacked_init(init_fn, key, n):
    """init_fn(key) -> (params, ax).  Returns params stacked on axis 0."""
    _, ax = init_fn(key)  # structure + axes only (arrays discarded)
    keys = jax.random.split(key, n)
    stacked = jax.vmap(lambda k: init_fn(k)[0])(keys)
    return stacked, _stack_axes(ax)


# ---------------------------------------------------------------------------
# per-family layer inits
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg: ModelConfig, with_cross: bool = False):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {}
    ax: dict[str, Any] = {}
    p["ln1"], ax["ln1"] = init_norm(cfg)
    p["attn"], ax["attn"] = attn.init_attention(ks[0], cfg)
    if not cfg.parallel_block:
        p["ln2"], ax["ln2"] = init_norm(cfg)
    if with_cross:
        p["lnx"], ax["lnx"] = init_norm(cfg)
        p["xattn"], ax["xattn"] = attn.init_attention(ks[1], cfg)
    if cfg.family == "moe":
        p["moe"], ax["moe"] = init_moe(ks[2], cfg)
    else:
        p["mlp"], ax["mlp"] = init_mlp(ks[3], cfg)
    return p, ax


def _init_ssm_layer(key, cfg: ModelConfig):
    p: dict[str, Any] = {}
    ax: dict[str, Any] = {}
    p["ln1"], ax["ln1"] = init_norm(cfg)
    if cfg.ssm_version == 1:
        p["mamba"], ax["mamba"] = ssm.init_mamba1(key, cfg)
    else:
        p["mamba"], ax["mamba"] = ssm.init_mamba2(key, cfg)
    return p, ax


def init_params(cfg: ModelConfig, key) -> Params:
    p, _ = _init_all(cfg, key)
    return p


def param_logical_axes(cfg: ModelConfig):
    box = {}

    def f():
        p, ax = _init_all(cfg, jax.random.PRNGKey(0))
        box["ax"] = ax
        return p

    jax.eval_shape(f)
    return box["ax"]


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of params (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def _init_all(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    ax: dict[str, Any] = {}
    p["embed"], ax["embed"] = init_embed(ks[0], cfg)
    p["final_norm"], ax["final_norm"] = init_norm(cfg)

    if cfg.family in ("dense", "vlm", "moe"):
        p["layers"], ax["layers"] = _stacked_init(
            lambda k: _init_dense_layer(k, cfg), ks[1], cfg.num_layers)
    elif cfg.family == "ssm":
        p["layers"], ax["layers"] = _stacked_init(
            lambda k: _init_ssm_layer(k, cfg), ks[1], cfg.num_layers)
    elif cfg.family == "hybrid":
        p["layers"], ax["layers"] = _stacked_init(
            lambda k: _init_ssm_layer(k, cfg), ks[1], cfg.num_layers)
        p["shared_attn"], ax["shared_attn"] = _init_dense_layer(ks[2], cfg)
    elif cfg.family == "audio":
        p["layers"], ax["layers"] = _stacked_init(
            lambda k: _init_dense_layer(k, cfg, with_cross=True), ks[1], cfg.num_layers)
        enc_cfg = cfg
        p["enc_layers"], ax["enc_layers"] = _stacked_init(
            lambda k: _init_dense_layer(k, enc_cfg), ks[3], cfg.encoder_layers)
        p["enc_norm"], ax["enc_norm"] = init_norm(cfg)
    else:
        raise ValueError(cfg.family)
    return p, ax


# ---------------------------------------------------------------------------
# layer application (training / prefill / decode share one code path)
# ---------------------------------------------------------------------------

def _dense_layer_apply(lp, x, cfg: ModelConfig, positions, *, causal=True,
                       cache=None, pos=None, enc_kv=None, causal_skip=False):
    """Returns (x_out, (aux, zloss), new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)
    h = apply_norm(lp["ln1"], x, cfg)
    q, k, v = attn.qkv_project(lp["attn"], h, cfg, positions)
    new_cache = cache
    if cache is None:
        o = attn.blockwise_attention(q, k, v, cfg, causal=causal, causal_skip=causal_skip)
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        length = jnp.full((x.shape[0],), pos + q.shape[1], jnp.int32)
        from repro.perf import get_flags as _gf
        if cache.get("seq_shard", False):
            seq_mode = "data"                       # long_500k shapes
        elif _gf().serve_seq_sharded_kv and not cfg.shard_kv_heads:
            seq_mode = "model"                      # PerfFlags serving layout
        else:
            seq_mode = False
        o = attn.decode_attention(q, ck, cv, length, cfg, seq_shard=seq_mode)
        new_cache = dict(cache, k=ck, v=cv)
    from repro.perf import get_flags

    if cfg.parallel_block and get_flags().parallel_fused_ar:
        # Sum the attn and mlp partial outputs BEFORE any sharding constraint:
        # the tensor-parallel combine becomes ONE all-reduce per layer.
        B_, S_ = o.shape[:2]
        hm = attn.head_mask(cfg)
        if hm is not None:
            o = o * jnp.asarray(hm, o.dtype)[None, None, :, None]
        dt = _dtype(cfg)
        a_part = o.reshape(B_, S_, -1).astype(dt) @ lp["attn"]["wo"].astype(dt)
        g = h.astype(dt) @ lp["mlp"]["wg"].astype(dt)
        u = h.astype(dt) @ lp["mlp"]["wi"].astype(dt)
        m_part = (jax.nn.silu(g) * u) @ lp["mlp"]["wo"].astype(dt)
        out = constrain(a_part + m_part, ("batch", "seq", "embed"))
        return x + out, (aux, zl), new_cache

    a_out = attn.attn_output(lp["attn"], o, cfg)

    if cfg.parallel_block:
        m_out = apply_mlp(lp["mlp"], h, cfg)
        return x + a_out + m_out, (aux, zl), new_cache

    x = x + a_out
    if enc_kv is not None:  # cross attention (whisper decoder)
        hx = apply_norm(lp["lnx"], x, cfg)
        qx = hx.astype(_dtype(cfg)) @ lp["xattn"]["wq"].astype(_dtype(cfg))
        B, S = hx.shape[:2]
        qx = qx.reshape(B, S, cfg.num_padded_heads, cfg.head_dim)
        ek, ev, elen = enc_kv
        if S == 1:
            ox = attn.decode_attention(qx, ek, ev, elen, cfg)
        else:
            ox = attn.blockwise_attention(qx, ek, ev, cfg, causal=False)
        x = x + attn.attn_output(lp["xattn"], ox, cfg)

    h2 = apply_norm(lp["ln2"], x, cfg)
    if cfg.family == "moe":
        m_out, aux, zl = moe_block(lp["moe"], h2, cfg)
    else:
        m_out = apply_mlp(lp["mlp"], h2, cfg)
    return x + m_out, (aux, zl), new_cache


def _ssm_layer_apply(lp, x, cfg: ModelConfig, state=None):
    h = apply_norm(lp["ln1"], x, cfg)
    if cfg.ssm_version == 1:
        o, new_state = ssm.mamba1_block(lp["mamba"], h, cfg, state)
    else:
        o, new_state = ssm.mamba2_block(lp["mamba"], h, cfg, state)
    return x + o, new_state


def _maybe_remat(f, cfg: ModelConfig):
    if cfg.remat:
        return jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    return f


# ---------------------------------------------------------------------------
# trunk forward (training: full teacher-forced sequence -> final hidden)
# ---------------------------------------------------------------------------

def forward_hidden(params: Params, batch: dict, cfg: ModelConfig,
                   *, causal_skip: bool = False):
    """Returns (hidden (B,S,D) after final norm, aux_losses dict)."""
    if cfg.family == "vlm" and "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
    B, S = x.shape[:2]
    if cfg.mrope:
        positions = batch.get("positions")
        if positions is None:
            base = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
            positions = jnp.stack([base, base, base])
    else:
        positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)

    aux = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "moe"):
        f = _maybe_remat(
            lambda lp, x: _dense_layer_apply(lp, x, cfg, positions,
                                             causal_skip=causal_skip)[:2], cfg)

        def body(carry, lp):
            x, a, z = carry
            x, (da, dz) = f(lp, x)
            return (x, a + da, z + dz), None

        (x, aux, zl), _ = jax.lax.scan(body, (x, aux, zl), params["layers"])

    elif cfg.family == "ssm":
        f = _maybe_remat(lambda lp, x: _ssm_layer_apply(lp, x, cfg)[0], cfg)

        def body(x, lp):
            return f(lp, x), None

        x, _ = jax.lax.scan(body, x, params["layers"])

    elif cfg.family == "hybrid":
        n_super = cfg.num_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_super, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]

        def super_fn(sp, x):
            def inner(x, lp):
                return _ssm_layer_apply(lp, x, cfg)[0], None
            x, _ = jax.lax.scan(inner, x, sp)
            x, _, _ = _dense_layer_apply(shared, x, cfg, positions,
                                         causal_skip=causal_skip)
            return x

        f = _maybe_remat(super_fn, cfg)

        def body(x, sp):
            return f(sp, x), None

        x, _ = jax.lax.scan(body, x, stacked)

    elif cfg.family == "audio":
        enc = encode_audio(params, batch["frames"], cfg)
        elen = jnp.full((B,), enc.shape[1], jnp.int32)
        f = _maybe_remat(
            lambda lp, x, ek, ev: _dense_layer_apply(
                lp, x, cfg, positions, enc_kv=(ek, ev, elen),
                causal_skip=causal_skip)[:2], cfg)

        def body(carry, lp):
            x = carry
            ek, ev = _cross_kv(lp["xattn"], enc, cfg)
            x, _ = f(lp, x, ek, ev)
            return x, None

        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    return constrain(x, ("batch", "seq", "embed")), {"moe_aux": aux / max(cfg.num_layers, 1),
                                                     "moe_z": zl / max(cfg.num_layers, 1)}


def _cross_kv(xp, enc, cfg: ModelConfig):
    dt = _dtype(cfg)
    B, T, _ = enc.shape
    k = (enc.astype(dt) @ xp["wk"].astype(dt)).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = (enc.astype(dt) @ xp["wv"].astype(dt)).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def encode_audio(params: Params, frames, cfg: ModelConfig):
    """Whisper encoder over precomputed (stub) conv-frontend frame embeddings."""
    x = frames.astype(_dtype(cfg))
    B, T = x.shape[:2]
    positions = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
    f = _maybe_remat(
        lambda lp, x: _dense_layer_apply(lp, x, cfg, positions, causal=False)[0], cfg)

    def body(x, lp):
        return f(lp, x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg)


def forward(params: Params, batch: dict, cfg: ModelConfig):
    """Full logits (small models / tests only — O(B,S,V) memory)."""
    h, aux = forward_hidden(params, batch, cfg)
    return unembed(params["embed"], h, cfg), aux


# ---------------------------------------------------------------------------
# loss (chunked over sequence to bound the logits buffer)
# ---------------------------------------------------------------------------

def lm_loss(params: Params, batch: dict, cfg: ModelConfig,
            *, causal_skip: bool = False):
    h, aux = forward_hidden(params, batch, cfg, causal_skip=causal_skip)
    tokens = batch["tokens"]
    B, S = tokens.shape
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate([jnp.ones((B, S - 1), jnp.float32),
                            jnp.zeros((B, 1), jnp.float32)], axis=1)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"].astype(jnp.float32)

    C = min(LOSS_CHUNK, S)
    assert S % C == 0
    n = S // C
    hr = h.reshape(B, n, C, -1).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, n, C).transpose(1, 0, 2)
    mr = mask.reshape(B, n, C).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        hc, lc, mc = inp
        logits = unembed(params["embed"], hc, cfg).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hr, lr, mr))
    ntok = jnp.maximum(mask.sum(), 1.0)
    loss = total / ntok
    if cfg.family == "moe":
        loss = loss + cfg.aux_loss_coef * aux["moe_aux"] + cfg.router_z_coef * aux["moe_z"]
    return loss, {"loss": loss, "ntok": ntok, **aux}


# ---------------------------------------------------------------------------
# decode: state init / prefill / single-token step
# ---------------------------------------------------------------------------

def _kv_cache_init(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                   seq_shard: bool):
    dt = jnp.dtype(cfg.dtype)
    kv = {
        "k": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
    }
    if seq_shard:
        kv["seq_shard"] = jnp.ones((n_layers,), jnp.bool_)
    return kv


def _kv_cache_axes(cfg: ModelConfig, seq_shard: bool):
    from repro.perf import get_flags

    seq_ax = "kv_seq_shard" if seq_shard else "kv_seq"
    kv_ax = "kv_heads" if cfg.shard_kv_heads else None
    if (get_flags().serve_seq_sharded_kv and not seq_shard
            and not cfg.shard_kv_heads):
        # KV heads are not TP-divisible -> the cache would replicate over the
        # model axis and overflow HBM at 32k; shard its sequence dim instead
        # (sharded-softmax decode handles it like the long_500k path).
        seq_ax = "kv_seq_model"
    # long_500k runs at global_batch=1: the batch dim cannot shard — the
    # sequence axis carries the data-parallel split instead.
    b_ax = None if seq_shard else "batch"
    ax = {"k": ("layers", b_ax, seq_ax, kv_ax, None),
          "v": ("layers", b_ax, seq_ax, kv_ax, None)}
    if seq_shard:
        ax["seq_shard"] = ("layers",)
    return ax


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      *, seq_shard: bool = False) -> dict:
    st: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe"):
        st["kv"] = _kv_cache_init(cfg, batch, max_len, cfg.num_layers, seq_shard)
    elif cfg.family == "ssm":
        one = (ssm.mamba1_state_init(cfg, batch) if cfg.ssm_version == 1
               else ssm.mamba2_state_init(cfg, batch))
        st["ssm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), one)
    elif cfg.family == "hybrid":
        one = ssm.mamba2_state_init(cfg, batch)
        st["ssm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), one)
        n_super = cfg.num_layers // cfg.attn_every
        st["kv"] = _kv_cache_init(cfg, batch, max_len, n_super, seq_shard)
    elif cfg.family == "audio":
        st["kv"] = _kv_cache_init(cfg, batch, max_len, cfg.num_layers, False)
        dt = jnp.dtype(cfg.dtype)
        st["enc_kv"] = {
            "k": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    return st


def _no_batch(ax_tree):
    """Replace the 'batch' logical axis with None (batch=1 decode shapes)."""
    return jax.tree.map(
        lambda a: tuple(None if x == "batch" else x for x in a),
        ax_tree, is_leaf=_is_ax)


def decode_state_logical_axes(cfg: ModelConfig, *, seq_shard: bool = False):
    ax: dict[str, Any] = {"pos": ()}
    if cfg.family in ("dense", "vlm", "moe"):
        ax["kv"] = _kv_cache_axes(cfg, seq_shard)
    elif cfg.family == "ssm":
        one = (ssm.mamba1_state_axes() if cfg.ssm_version == 1
               else ssm.mamba2_state_axes())
        ax["ssm"] = _stack_axes(one)
        if seq_shard:
            ax["ssm"] = _no_batch(ax["ssm"])
    elif cfg.family == "hybrid":
        ax["ssm"] = _stack_axes(ssm.mamba2_state_axes())
        if seq_shard:
            ax["ssm"] = _no_batch(ax["ssm"])
        ax["kv"] = _kv_cache_axes(cfg, seq_shard)
    elif cfg.family == "audio":
        ax["kv"] = _kv_cache_axes(cfg, False)
        kv_ax = "kv_heads" if cfg.shard_kv_heads else None
        ax["enc_kv"] = {"k": ("layers", "batch", None, kv_ax, None),
                        "v": ("layers", "batch", None, kv_ax, None),
                        "len": ("batch",)}
    return ax


def decode_step(params: Params, state: dict, token: jax.Array, cfg: ModelConfig):
    """token: (B,) int32.  Returns (logits (B,V), new_state)."""
    B = token.shape[0]
    pos = state["pos"]
    x = embed_tokens(params["embed"], token[:, None], cfg)          # (B,1,D)
    if cfg.mrope:
        p1 = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        positions = jnp.stack([p1, p1, p1])
    else:
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

    new_state = dict(state, pos=pos + 1)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(x, inp):
            lp, ck, cv = inp
            cache = {"k": ck, "v": cv}
            if "seq_shard" in state["kv"]:
                cache["seq_shard"] = True
            x, _, nc = _dense_layer_apply(lp, x, cfg, positions, cache=cache, pos=pos)
            return x, (nc["k"], nc["v"])

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"],
                                             state["kv"]["k"], state["kv"]["v"]))
        new_state["kv"] = dict(state["kv"], k=nk, v=nv)

    elif cfg.family == "ssm":
        def body(x, inp):
            lp, st = inp
            x, ns = _ssm_layer_apply(lp, x, cfg, state=st)
            return x, ns

        x, nss = jax.lax.scan(body, x, (params["layers"], state["ssm"]))
        new_state["ssm"] = nss

    elif cfg.family == "hybrid":
        n_super = cfg.num_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_super, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        sstates = jax.tree.map(
            lambda a: a.reshape((n_super, cfg.attn_every) + a.shape[1:]),
            state["ssm"])
        shared = params["shared_attn"]

        def body(x, inp):
            sp, st, ck, cv = inp

            def inner(x, li):
                lp, lst = li
                x, ns = _ssm_layer_apply(lp, x, cfg, state=lst)
                return x, ns

            x, nst = jax.lax.scan(inner, x, (sp, st))
            cache = {"k": ck, "v": cv}
            if "seq_shard" in state["kv"]:
                cache["seq_shard"] = True
            x, _, nc = _dense_layer_apply(shared, x, cfg, positions, cache=cache, pos=pos)
            return x, (nst, nc["k"], nc["v"])

        x, (nss, nk, nv) = jax.lax.scan(
            body, x, (stacked, sstates, state["kv"]["k"], state["kv"]["v"]))
        new_state["ssm"] = jax.tree.map(
            lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), nss)
        new_state["kv"] = dict(state["kv"], k=nk, v=nv)

    elif cfg.family == "audio":
        ek, ev = state["enc_kv"]["k"], state["enc_kv"]["v"]
        elen = state["enc_kv"]["len"]

        def body(x, inp):
            lp, ck, cv, eki, evi = inp
            cache = {"k": ck, "v": cv}
            x, _, nc = _dense_layer_apply(lp, x, cfg, positions, cache=cache,
                                          pos=pos, enc_kv=(eki, evi, elen))
            return x, (nc["k"], nc["v"])

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"],
                                             state["kv"]["k"], state["kv"]["v"],
                                             ek, ev))
        new_state["kv"] = dict(state["kv"], k=nk, v=nv)
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, new_state


def prefill(params: Params, batch: dict, cfg: ModelConfig, max_len: int | None = None):
    """Prefill: one trunk pass that emits (last-position logits, decode state).

    Attention layers store their K/V into a fresh cache of size
    ``max_len or S``; SSM layers keep the chunked scan's final carry.
    """
    if "tokens" in batch:
        B, S = batch["tokens"].shape
    else:
        B, S = batch["embeds"].shape[:2]
    max_len = max_len or S
    state = init_decode_state(cfg, B, max_len)
    x_final, state = _fill_state(params, batch, cfg, state, max_len)
    state["pos"] = jnp.asarray(S, jnp.int32)
    h = apply_norm(params["final_norm"], x_final[:, -1:], cfg)
    logits = unembed(params["embed"], h, cfg)[:, 0]
    return logits, state


def _ssm_layer_capture(lp, x, cfg: ModelConfig):
    """SSM layer forward that also returns the final scan state (prefill)."""
    h = apply_norm(lp["ln1"], x, cfg)
    if cfg.ssm_version == 1:
        o, st = ssm.mamba1_block(lp["mamba"], h, cfg, return_final_state=True)
    else:
        o, st = ssm.mamba2_block(lp["mamba"], h, cfg, return_final_state=True)
    return x + o, st


def _fill_state(params, batch, cfg, state, max_len):
    """One capture pass over the trunk filling KV caches and/or SSM states."""
    if cfg.family == "vlm" and "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
    B, S = x.shape[:2]
    if cfg.mrope:
        positions = batch.get("positions")
        if positions is None:
            base = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
            positions = jnp.stack([base, base, base])
    else:
        positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    pad = max_len - S
    kdt = jnp.dtype(cfg.dtype)

    def padded(k, v):
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kdt)
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kdt)
        return kp, vp

    if cfg.family in ("dense", "vlm", "moe"):
        def body(x, lp):
            h = apply_norm(lp["ln1"], x, cfg)
            _, k, v = attn.qkv_project(lp["attn"], h, cfg, positions)
            x, _, _ = _dense_layer_apply(lp, x, cfg, positions)
            return x, padded(k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        return x, dict(state, kv=dict(state["kv"], k=ks, v=vs))

    if cfg.family == "ssm":
        def body(x, lp):
            x, st = _ssm_layer_capture(lp, x, cfg)
            return x, st

        x, sstates = jax.lax.scan(body, x, params["layers"])
        return x, dict(state, ssm=sstates)

    if cfg.family == "hybrid":
        n_super = cfg.num_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_super, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]

        def body(x, sp):
            def inner(x, lp):
                return _ssm_layer_capture(lp, x, cfg)

            x, sst = jax.lax.scan(inner, x, sp)
            h = apply_norm(shared["ln1"], x, cfg)
            _, k, v = attn.qkv_project(shared["attn"], h, cfg, positions)
            x, _, _ = _dense_layer_apply(shared, x, cfg, positions)
            return x, (sst, *padded(k, v))

        x, (sst, ks, vs) = jax.lax.scan(body, x, stacked)
        # (n_super, attn_every, ...) -> (num_layers, ...)
        sstates = jax.tree.map(lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), sst)
        return x, dict(state, ssm=sstates, kv=dict(state["kv"], k=ks, v=vs))

    if cfg.family == "audio":
        enc = encode_audio(params, batch["frames"], cfg)
        elen = jnp.full((B,), enc.shape[1], jnp.int32)

        def body(x, lp):
            h = apply_norm(lp["ln1"], x, cfg)
            _, k, v = attn.qkv_project(lp["attn"], h, cfg, positions)
            ek, ev = _cross_kv(lp["xattn"], enc, cfg)
            x, _, _ = _dense_layer_apply(lp, x, cfg, positions, enc_kv=(ek, ev, elen))
            return x, (padded(k, v), (ek.astype(kdt), ev.astype(kdt)))

        x, ((ks, vs), (eks, evs)) = jax.lax.scan(body, x, params["layers"])
        enc_kv = {"k": eks, "v": evs,
                  "len": jnp.full((B,), enc.shape[1], jnp.int32)}
        return x, dict(state, kv=dict(state["kv"], k=ks, v=vs), enc_kv=enc_kv)

    raise ValueError(cfg.family)
