"""Model configuration for the analytics-backbone zoo.

One frozen dataclass covers all six families (dense / ssm / hybrid / moe / vlm /
audio).  Family-specific fields are zero/None when unused.  Configs for the ten
assigned architectures live in ``repro.configs``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "ssm", "hybrid", "moe", "vlm", "audio"]

# Tensor-parallel degree of the production mesh (model axis).  Head counts are
# zero-padded and non-divisible vocab/kv dims are replicated against this
# (DESIGN.md §5) — the mesh's model axis is fixed at 16 in both the single-pod
# and multi-pod configurations.
TP_DEGREE = 16


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                 # 0 -> d_model // num_heads
    real_vocab_size: int = 0          # >0: vocab_size is padded; mask pads

    # --- normalization / block style ---
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    parallel_block: bool = False      # command-r style: attn and mlp in parallel
    use_bias: bool = False
    tie_embeddings: bool = False

    # --- positional ---
    rope_theta: float = 10_000.0
    mrope: bool = False               # qwen2-vl M-RoPE (3 position streams)
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # fractions of head_dim/2

    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_version: int = 1              # 1 = mamba1 (falcon-mamba), 2 = mamba2 (zamba2)
    ssm_head_dim: int = 64            # mamba2 head dim
    ssm_chunk: int = 256              # chunked-scan chunk length

    # --- hybrid (zamba2): shared attention block applied every attn_every layers ---
    attn_every: int = 0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500           # post-conv audio frame count (stub frontend)
    cross_attn: bool = False

    # --- compute ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    attn_q_chunk: int = 512           # blockwise attention tiling (pure-JAX path)
    attn_kv_chunk: int = 1024
    remat: bool = True                # rematerialize each layer in the scan
    scan_layers: bool = True          # stack homogeneous layers and lax.scan
    use_pallas: bool = False          # TPU target: route hotspots to Pallas kernels
    logits_softcap: float = 0.0

    # vlm stub: patch-embedding input instead of token ids for the vision stream
    vision_stub: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        assert self.family in ("dense", "ssm", "hybrid", "moe", "vlm", "audio")
        if self.family in ("dense", "vlm", "moe", "audio"):
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family == "hybrid":
            assert self.attn_every > 0 and self.ssm_state > 0
        if self.family == "ssm":
            assert self.ssm_state > 0

    # ---- derived sizes ----
    @property
    def d_head(self) -> int:
        return self.head_dim

    @property
    def num_padded_heads(self) -> int:
        """Query heads zero-padded up to a TP_DEGREE multiple (inert pads)."""
        h = max(self.num_heads, 1)
        return -(-h // TP_DEGREE) * TP_DEGREE if h % TP_DEGREE else h

    @property
    def shard_kv_heads(self) -> bool:
        return self.num_kv_heads % TP_DEGREE == 0

    @property
    def shard_vocab(self) -> bool:
        return self.vocab_size % TP_DEGREE == 0

    def with_padded_vocab(self) -> "ModelConfig":
        """Pad the vocab to a TP_DEGREE multiple (PerfFlags.pad_vocab): the
        embedding rows/logit columns beyond the real vocab are masked to
        -inf in the unembed, so the softmax/CE are unchanged while the
        vocab dim becomes shardable (kills the unsharded-logits all-reduce
        in the loss backward — see EXPERIMENTS.md whisper note)."""
        if self.vocab_size % TP_DEGREE == 0:
            return self
        padded = -(-self.vocab_size // TP_DEGREE) * TP_DEGREE
        return dataclasses.replace(self, vocab_size=padded,
                                   real_vocab_size=self.vocab_size)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))  # ceil(d_model/16), mamba1 default

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6·N·D)."""
        D, V = self.d_model, self.vocab_size
        n = V * D  # embeddings
        if not self.tie_embeddings:
            n += V * D
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        dense_mlp = 3 * D * self.d_ff
        norm = 2 * D

        def mamba1():
            di, ds, dtr = self.d_inner, self.ssm_state, self.dt_rank
            return (D * 2 * di + di * self.ssm_conv + di * (dtr + 2 * ds)
                    + dtr * di + di * ds + di + di * D + D)

        def mamba2():
            di, ds = self.d_inner, self.ssm_state
            nh = self.ssm_num_heads
            return (D * (2 * di + 2 * ds + nh) + (di + 2 * ds) * self.ssm_conv
                    + nh + nh + di + di * D + D)

        if self.family == "ssm":
            n += self.num_layers * (mamba1() if self.ssm_version == 1 else mamba2())
            n += D  # final norm
            return n
        if self.family == "hybrid":
            n += self.num_layers * (mamba2() + norm)
            n += (attn + dense_mlp + norm)  # one shared attention block
            n += D
            return n
        if self.family == "moe":
            per_expert = 3 * D * self.d_ff
            n += self.num_layers * (attn + self.num_experts * per_expert
                                    + D * self.num_experts + norm)
            n += D
            return n
        # dense / vlm / audio decoder
        dec_layers = self.num_layers
        n += dec_layers * (attn + dense_mlp + norm)
        if self.is_encdec:
            n += self.encoder_layers * (attn + dense_mlp + norm)
            n += dec_layers * (attn + D)  # cross attention + its norm
            n += D  # encoder final norm
        n += D
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        D = self.d_model
        per_expert = 3 * D * self.d_ff
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * per_expert
        return self.param_count() - inactive
