"""REPRO_SANITIZE=1 — opt-in runtime sanitizers for local debugging.

Enabled sanitizers:

  * ``jax_debug_nans``: every jit dispatch re-checks outputs for NaNs and
    re-runs de-optimized to locate the producing primitive.
  * transport-callback reentrancy assertions: a ``Transport.on_dead``
    callback must never re-enter the transport it is being fired from
    (``fetch_async``/``wait_fetch`` during dead-peer dispatch would
    deadlock a real RPC backend; the in-process fakes would just silently
    reorder the fault schedule).

This module must stay dependency-light (stdlib + jax only): it is imported
by ``repro.runtime.transport``, which sits below everything else in the
runtime stack.
"""
from __future__ import annotations

import os

_enabled: bool | None = None   # tri-state: None = read env on first use


def _env_on() -> bool:
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() in (
        "1", "true", "yes", "on")


def enabled() -> bool:
    """Are the sanitizers on?  First call latches the REPRO_SANITIZE env."""
    global _enabled
    if _enabled is None:
        if _env_on():
            enable()
        else:
            _enabled = False
    return _enabled


def enable() -> None:
    """Turn the sanitizers on for this process (idempotent)."""
    global _enabled
    import jax
    jax.config.update("jax_debug_nans", True)
    _enabled = True


def disable() -> None:
    """Turn the sanitizers off (tests use this to restore global state)."""
    global _enabled
    import jax
    jax.config.update("jax_debug_nans", False)
    _enabled = False


def maybe_enable_from_env() -> bool:
    """Latch REPRO_SANITIZE once at process entry (repro.api import time)."""
    return enabled()
