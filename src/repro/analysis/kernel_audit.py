"""Static checks over the Pallas kernels.

Two halves:

1. **Grid/BlockSpec bounds proof.**  ``pl.pallas_call`` is intercepted (no
   kernel executes) to capture every call's grid, BlockSpecs and padded
   operand shapes; each index map is then evaluated at EVERY grid point and
   each block offset checked in bounds for the operand it addresses.  The
   wrapper sweep covers ragged shapes (Q/G/C/D far from the block sizes) so
   the pow2/round_up padding arithmetic is what's actually proved.

2. **Sentinel-convention probes.**  The tie-break differentials
   (tracker<->engine, single<->fleet) rely on every masked/padded slot
   ranking to exactly ``(NEG_INF, -1)``.  Tiny interpret-mode probes pin
   that for: bands beyond the gallery size, fully-masked queries,
   frame-mismatched galleries, and the empty-gallery fast path — plus the
   NEG_INF constant itself.
"""
from __future__ import annotations

import contextlib
import itertools

import numpy as np

from repro.analysis.lint import Violation

__all__ = ["audit_kernels", "capture_pallas_calls", "check_record"]

# grid-point enumeration budget per captured call (probes are tiny; a grid
# this large in an audit fixture is itself a bug)
_MAX_GRID_POINTS = 200_000


class _Captured(Exception):
    """Raised by the intercepted pallas_call to abort wrapper execution."""


@contextlib.contextmanager
def capture_pallas_calls(records: list):
    """Monkeypatch ``pl.pallas_call`` to record (kernel, grid, specs,
    operand shapes) and abort before execution.  Call sites must catch
    ``_Captured`` — use ``_capture_call`` below."""
    from jax.experimental import pallas as pl
    real = pl.pallas_call

    def fake(kernel, **kw):
        def runner(*operands):
            records.append(dict(
                kernel=getattr(getattr(kernel, "func", kernel), "__name__",
                               str(kernel)),
                grid=kw.get("grid"),
                in_specs=list(kw.get("in_specs") or []),
                out_specs=kw.get("out_specs"),
                out_shape=kw.get("out_shape"),
                operand_shapes=[tuple(np.shape(o)) for o in operands],
            ))
            raise _Captured
        return runner

    pl.pallas_call = fake
    try:
        yield records
    finally:
        pl.pallas_call = real


def _capture_call(fn, *args, **kwargs) -> list[dict]:
    records: list[dict] = []
    with capture_pallas_calls(records):
        try:
            fn(*args, **kwargs)
        except _Captured:
            pass
    return records


def _as_list(x):
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def check_record(rec: dict) -> list[Violation]:
    """Prove every BlockSpec index map in bounds over the full grid."""
    out: list[Violation] = []
    where = f"<pallas:{rec['kernel']}>"
    grid = rec["grid"]
    grid = (grid,) if isinstance(grid, int) else tuple(grid or ())
    total = 1
    for g in grid:
        total *= g
    if total > _MAX_GRID_POINTS:
        out.append(Violation("PALLAS", where, 0,
                             f"grid {grid} too large to enumerate "
                             f"({total} points) — shrink the audit shapes"))
        return out

    out_shapes = [tuple(s.shape) for s in _as_list(rec["out_shape"])]
    pairs = list(zip(rec["in_specs"], rec["operand_shapes"])) + \
        list(zip(_as_list(rec["out_specs"]), out_shapes))
    for argno, (spec, shape) in enumerate(pairs):
        block = getattr(spec, "block_shape", None)
        imap = getattr(spec, "index_map", None)
        if block is None or imap is None:
            continue
        bad = 0
        for point in itertools.product(*map(range, grid)):
            idx = imap(*point)
            idx = tuple(idx) if isinstance(idx, (tuple, list)) else (idx,)
            if len(idx) != len(block) or len(block) != len(shape):
                out.append(Violation(
                    "PALLAS", where, 0,
                    f"arg {argno}: index map rank {len(idx)} vs block rank "
                    f"{len(block)} vs operand rank {len(shape)}"))
                bad += 1
                break
            for off, blk, dim in zip(idx, block, shape):
                blk = dim if blk is None else blk
                if off < 0 or (int(off) + 1) * blk > dim:
                    out.append(Violation(
                        "PALLAS", where, 0,
                        f"arg {argno}: block offset {idx} x block {block} "
                        f"out of bounds for operand shape {shape} at grid "
                        f"point {point}"))
                    bad += 1
                    break
            if bad:
                break   # one finding per (call, arg) is enough
    return out


# ---------------------------------------------------------------------------
# Shape sweeps: ragged (Q, G, C, D) far from the block sizes, so the
# pow2/round_up padding paths are what gets proved.
# ---------------------------------------------------------------------------

def _bounds_findings() -> list[Violation]:
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.mamba_scan import mamba_scan
    from repro.kernels.reid_topk import (reid_topk, reid_topk_masked,
                                         reid_topk_segments, reid_topk_tiles)

    rng = np.random.default_rng(3)
    records: list[dict] = []

    for Q, G, D, k in [(1, 1, 8, 1), (5, 120, 16, 3), (100, 700, 32, 2),
                       (130, 1024, 64, 1), (8, 129, 8, 4)]:
        q = rng.normal(size=(Q, D)).astype(np.float32)
        g = rng.normal(size=(G, D)).astype(np.float32)
        records += _capture_call(reid_topk, q, g, k)

    for Q, C, G, k in [(1, 4, 1, 1), (5, 30, 120, 3), (100, 30, 700, 2),
                       (16, 130, 257, 1)]:
        q = rng.normal(size=(Q, 16)).astype(np.float32)
        qf = rng.integers(0, 9, Q).astype(np.int32)
        adm = rng.integers(0, 2, (Q, C)).astype(bool)
        g = rng.normal(size=(G, 16)).astype(np.float32)
        gc = rng.integers(0, C, G).astype(np.int32)
        gf = rng.integers(0, 9, G).astype(np.int32)
        records += _capture_call(reid_topk_masked, q, qf, adm, g, gc, gf, k)
        # the segment-ID entry shares the padded call; sweep it over the
        # same ragged shapes so a divergence in its padding arithmetic
        # cannot hide behind the frame-tag variant
        qs = rng.integers(0, 5, Q).astype(np.int32)
        gs = rng.integers(0, 5, G).astype(np.int32)
        records += _capture_call(reid_topk_segments, q, qs, adm, g, gc,
                                 gs, k)
        # the tile-granular entry widens the admission axis to C*T*T fused
        # cells; sweep it over the same ragged shapes (plus unlabeled -1
        # rows) so its CT-axis round_up padding is proved alongside
        TT = 4
        adm_ct = rng.integers(0, 2, (Q, C * TT)).astype(bool)
        g_ct = np.where(rng.random(G) < 0.1, -1,
                        gc * TT + rng.integers(0, TT, G)).astype(np.int32)
        records += _capture_call(reid_topk_tiles, q, qs, adm_ct, g, g_ct,
                                 gs, k)

    for B, H, S, hd, KV, T in [(2, 4, 256, 64, 2, 512), (1, 2, 512, 32, 2, 256)]:
        q = rng.normal(size=(B, H, S, hd)).astype(np.float32)
        kv = rng.normal(size=(B, KV, T, hd)).astype(np.float32)
        records += _capture_call(flash_attention, q, kv, kv)

    B, H, hd, KV, T = 2, 4, 64, 2, 1024
    import jax.numpy as jnp
    q = rng.normal(size=(B, H, hd)).astype(np.float32)
    kv = rng.normal(size=(B, KV, T, hd)).astype(np.float32)
    length = jnp.asarray(rng.integers(1, T, B), jnp.int32)
    records += _capture_call(decode_attention, q, kv, kv, length)

    B, L, D, N = 2, 256, 256, 16
    u = rng.normal(size=(B, L, D)).astype(np.float32)
    bm = rng.normal(size=(B, L, N)).astype(np.float32)
    A = rng.normal(size=(D, N)).astype(np.float32)
    records += _capture_call(mamba_scan, u, u, bm, bm, A)

    out: list[Violation] = []
    if not records:
        out.append(Violation("PALLAS", "<pallas:capture>", 0,
                             "no pallas_call captured — did the kernel "
                             "wrappers stop calling pl.pallas_call?"))
    for rec in records:
        out.extend(check_record(rec))
    return out


# ---------------------------------------------------------------------------
# Sentinel-convention probes (interpret mode, tiny shapes)
# ---------------------------------------------------------------------------

def _sentinel_findings() -> list[Violation]:
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.reid_topk import NEG_INF

    out: list[Violation] = []

    def expect(cond: bool, msg: str):
        if not cond:
            out.append(Violation("PALLAS", "<pallas:sentinel>", 0, msg))

    expect(float(NEG_INF) == -1e30,
           f"NEG_INF is {NEG_INF!r}, expected -1e30 — the sentinel the "
           "tie-break differentials encode")

    rng = np.random.default_rng(5)
    D = 8
    q = jnp.asarray(rng.normal(size=(3, D)), jnp.float32)

    # bands beyond the gallery size come back (NEG_INF, -1)
    g = jnp.asarray(rng.normal(size=(2, D)), jnp.float32)
    sv, si = ops.reid_topk(q, g, 5, interpret=True)
    sv, si = np.asarray(sv), np.asarray(si)
    expect(bool((sv[:, 2:] == NEG_INF).all() and (si[:, 2:] == -1).all()),
           "reid_topk: bands beyond the gallery are not (NEG_INF, -1)")

    # empty gallery: the host fast path must return the same sentinel
    sv, si = ops.reid_topk(q, jnp.zeros((0, D), jnp.float32), 3,
                           interpret=True)
    expect(bool((np.asarray(sv) == NEG_INF).all()
                and (np.asarray(si) == -1).all()),
           "reid_topk: empty gallery does not return (NEG_INF, -1)")

    # fully-masked query rows (admit all-False) rank to the sentinel
    C, G = 4, 6
    g = jnp.asarray(rng.normal(size=(G, D)), jnp.float32)
    gc = jnp.asarray(rng.integers(0, C, G), jnp.int32)
    gf = jnp.full((G,), 7, jnp.int32)
    qf = jnp.full((3,), 7, jnp.int32)
    adm = jnp.zeros((3, C), bool).at[1].set(True)   # rows 0/2 fully masked
    sv, si = ops.reid_topk_masked(q, qf, adm, g, gc, gf, 2, interpret=True)
    sv, si = np.asarray(sv), np.asarray(si)
    expect(bool((sv[[0, 2]] == NEG_INF).all() and (si[[0, 2]] == -1).all()),
           "reid_topk_masked: fully-masked rows are not (NEG_INF, -1)")
    expect(bool((si[1] >= 0).all()),
           "reid_topk_masked: an admitted row with matching frames "
           "unexpectedly hit the sentinel")

    # frame mismatch masks every row the same way
    sv, si = ops.reid_topk_masked(q, qf, jnp.ones((3, C), bool), g, gc,
                                  gf + 1, 2, interpret=True)
    expect(bool((np.asarray(sv) == NEG_INF).all()
                and (np.asarray(si) == -1).all()),
           "reid_topk_masked: frame-mismatched galleries are not "
           "(NEG_INF, -1)")

    # the segment-ID entry: an injective relabeling of the frame tags must
    # be bit-identical to the frame variant (the consolidation plane's
    # trace-identity contract) ...
    q_seg = jnp.full((3,), 2, jnp.int32)        # frame 7 -> segment 2
    g_seg = jnp.full((G,), 2, jnp.int32)
    ssv, ssi = ops.reid_topk_segments(q, q_seg, adm, g, gc, g_seg, 2,
                                      interpret=True)
    msv, msi = ops.reid_topk_masked(q, qf, adm, g, gc, gf, 2,
                                    interpret=True)
    expect(bool(np.array_equal(np.asarray(ssv), np.asarray(msv))
                and np.array_equal(np.asarray(ssi), np.asarray(msi))),
           "reid_topk_segments: relabeled segment tags diverge from the "
           "frame-tag variant")
    # ... and a segment mismatch masks every row to the sentinel
    ssv, ssi = ops.reid_topk_segments(q, q_seg, jnp.ones((3, C), bool), g,
                                      gc, g_seg + 1, 2, interpret=True)
    expect(bool((np.asarray(ssv) == NEG_INF).all()
                and (np.asarray(ssi) == -1).all()),
           "reid_topk_segments: segment-mismatched galleries are not "
           "(NEG_INF, -1)")

    # the tile-granular entry: with EVERY tile admitted the fused
    # (camera, tile) cells reduce to camera admission, so the kernel must
    # be bit-identical to the segment variant (the tile differential's
    # trace-identity contract) ...
    TT = 4
    g_tile = jnp.asarray(rng.integers(0, TT, G), jnp.int32)
    g_ct = gc * TT + g_tile
    adm_ct = jnp.repeat(adm, TT, axis=1)        # admit_ct[q, c*TT+t]=adm[q,c]
    tsv, tsi = ops.reid_topk_tiles(q, q_seg, adm_ct, g, g_ct, g_seg, 2,
                                   interpret=True)
    expect(bool(np.array_equal(np.asarray(tsv), np.asarray(msv))
                and np.array_equal(np.asarray(tsi), np.asarray(msi))),
           "reid_topk_tiles: all-tiles-admitted diverges from the "
           "segment variant")
    # ... an admitted camera whose TILE is masked ranks to the sentinel
    adm_wrong = jnp.zeros((3, C * TT), bool).at[:, 0].set(True)
    off_cell0 = jnp.where(g_ct == 0, 1, g_ct)   # no row sits in cell 0
    tsv, tsi = ops.reid_topk_tiles(q, q_seg, adm_wrong, g, off_cell0,
                                   g_seg, 2, interpret=True)
    expect(bool((np.asarray(tsv) == NEG_INF).all()
                and (np.asarray(tsi) == -1).all()),
           "reid_topk_tiles: tile-mismatched galleries are not "
           "(NEG_INF, -1)")
    # ... and unlabeled gallery rows (cell -1) match nothing even under
    # an all-admitted mask
    tsv, tsi = ops.reid_topk_tiles(q, q_seg, jnp.ones((3, C * TT), bool),
                                   g, jnp.full((G,), -1, jnp.int32),
                                   g_seg, 2, interpret=True)
    expect(bool((np.asarray(tsv) == NEG_INF).all()
                and (np.asarray(tsi) == -1).all()),
           "reid_topk_tiles: unlabeled (cell -1) rows are not (NEG_INF, -1)")
    return out


def audit_kernels() -> list[Violation]:
    """Bounds proofs + sentinel probes; empty list = clean."""
    return _bounds_findings() + _sentinel_findings()
