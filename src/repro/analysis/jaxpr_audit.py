"""Jaxpr-level audit of the registered jit entry points + RecompileGuard.

``audit_jaxprs`` traces every entry in ``repro.analysis.registry`` through
its real jit wrapper and walks the ClosedJaxpr (sub-jaxprs included — scan/
while/cond/pjit/shard_map/pallas bodies) for:

  * forbidden primitives — host callbacks (``pure_callback``,
    ``io_callback``, ``debug_callback``/debug prints, outfeed/infeed): a
    host sync inside the round body silently serializes the fleet;
  * f64 / complex128 avals — an accidental x64 promotion doubles the hot
    path's bandwidth and breaks cross-backend bit-reproducibility;
  * weak-typed ENTRY OUTPUTS — a weak output re-promotes downstream and
    makes the abstract signature depend on python scalar history;
  * non-integer (dynamic) shape dims — every entry must be fully
    shape-monomorphic or the compile cache can never converge.

``RecompileGuard`` is the runtime half of the compile-discipline story: it
snapshots each entry's jit cache size (count of compiled abstract
signatures) on enter and asserts at most ``max_new`` new signatures
appeared on exit.  Benchmarks enter it after warmup, so a steady-state
recompile (shape churn, a non-static kwarg, an epoch leaking into the
signature) fails fast instead of showing up as a 10x wall regression.
"""
from __future__ import annotations

from repro.analysis.lint import Violation

__all__ = ["FORBIDDEN_PRIMITIVES", "audit_closed_jaxpr", "audit_jaxprs",
           "RecompileError", "RecompileGuard"]

# Primitives that re-enter python or touch the host from inside a trace.
FORBIDDEN_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "outside_call", "host_callback_call", "infeed", "outfeed",
}

_BANNED_DTYPES = ("float64", "complex128")


def _sub_jaxprs(params: dict):
    import jax.core as jcore
    ClosedJaxpr = jcore.ClosedJaxpr
    Jaxpr = jcore.Jaxpr

    def _from(v):
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for x in v:
                yield from _from(x)
        elif isinstance(v, dict):
            for x in v.values():
                yield from _from(x)

    for v in params.values():
        yield from _from(v)


def _walk(jaxpr, visit, seen: set[int]):
    if id(jaxpr) in seen:
        return
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        visit(eqn)
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, visit, seen)


def audit_closed_jaxpr(name: str, closed) -> list[Violation]:
    """Audit one entry's ClosedJaxpr; findings report as rule ``JAXPR``."""
    out: list[Violation] = []
    where = f"<jit:{name}>"

    def visit(eqn):
        prim = eqn.primitive.name
        if prim in FORBIDDEN_PRIMITIVES:
            out.append(Violation(
                "JAXPR", where, 0,
                f"forbidden primitive `{prim}` — host callbacks/debug "
                "prints must not reach a registered serving entry"))
        for var in tuple(eqn.outvars) + tuple(eqn.invars):
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and str(dtype) in _BANNED_DTYPES:
                out.append(Violation(
                    "JAXPR", where, 0,
                    f"{dtype} aval at primitive `{prim}` — unexpected x64 "
                    "promotion in the hot path"))
            shape = getattr(aval, "shape", None)
            if shape is not None and not all(
                    isinstance(d, int) for d in shape):
                out.append(Violation(
                    "JAXPR", where, 0,
                    f"dynamic shape {shape} at primitive `{prim}` — entries "
                    "must be shape-monomorphic"))

    _walk(closed.jaxpr, visit, set())
    for i, aval in enumerate(closed.out_avals):
        if getattr(aval, "weak_type", False):
            out.append(Violation(
                "JAXPR", where, 0,
                f"output {i} is weak-typed ({aval}) — anneal with an "
                "explicit dtype before returning"))
    # duplicate findings (same aval flowing through many eqns) collapse
    return sorted(set(out), key=lambda v: (v.path, v.msg))


def audit_jaxprs(entries=None) -> list[Violation]:
    """Trace + audit every registered entry (see ``registry.entries``)."""
    from repro.analysis import registry
    if entries is None:
        entries = registry.entries()
    out: list[Violation] = []
    for e in entries:
        args, kwargs = e.example()
        traced = e.fn.trace(*args, **kwargs)
        out.extend(audit_closed_jaxpr(e.name, traced.jaxpr))
    return out


# ---------------------------------------------------------------------------
# RecompileGuard
# ---------------------------------------------------------------------------

class RecompileError(AssertionError):
    """A registered jit entry compiled more new signatures than allowed."""


class RecompileGuard:
    """Assert the serving loop's compile caches stay (near-)frozen.

    ``entries`` maps name -> jitted callable (anything exposing
    ``_cache_size()``); defaults to the module-level registry.  On exit, any
    entry that gained more than ``max_new`` compiled signatures raises
    ``RecompileError`` naming the offenders and their deltas.

        with RecompileGuard.for_engine(eng, max_new=1):
            for _ in range(steady_ticks):
                eng.tick()

    ``max_new=1`` encodes the acceptance contract: each entry compiles at
    most once after warmup (a genuinely new shape class may appear once;
    per-tick churn trips immediately).
    """

    def __init__(self, entries: dict | None = None, *, max_new: int = 0,
                 label: str = ""):
        if entries is None:
            from repro.analysis.registry import jit_entry_fns
            entries = jit_entry_fns()
        self.entries = dict(entries)
        self.max_new = max_new
        self.label = label
        self._base: dict[str, int] | None = None

    @classmethod
    def for_engine(cls, eng, *, max_new: int = 0, label: str = ""):
        """Registry entries plus — for a sharded fleet — the engine's
        CURRENT per-mesh shard_map jits."""
        from repro.analysis.registry import jit_entry_fns
        entries = jit_entry_fns()
        if hasattr(eng, "_fns"):           # ShardedServingEngine
            (f_admit, f_rank, f_rank_seg, f_advance, f_admit_tiles,
             f_rank_tiles) = eng._fns()
            entries["fleet.admit@shard_map"] = f_admit
            entries["fleet.rank_advance@shard_map"] = f_rank
            entries["fleet.rank_advance_seg@shard_map"] = f_rank_seg
            entries["fleet.advance@shard_map"] = f_advance
            entries["fleet.admit_tiles@shard_map"] = f_admit_tiles
            entries["fleet.rank_advance_tiles@shard_map"] = f_rank_tiles
        return cls(entries, max_new=max_new, label=label)

    @staticmethod
    def _size(fn) -> int:
        return int(fn._cache_size())

    def __enter__(self) -> "RecompileGuard":
        self._base = {n: self._size(f) for n, f in self.entries.items()}
        return self

    def new_compiles(self) -> dict[str, int]:
        assert self._base is not None, "guard not entered"
        return {n: self._size(f) - self._base[n]
                for n, f in self.entries.items()}

    def check(self) -> None:
        bad = {n: d for n, d in self.new_compiles().items()
               if d > self.max_new}
        if bad:
            tag = f" [{self.label}]" if self.label else ""
            detail = ", ".join(f"{n}: +{d}" for n, d in sorted(bad.items()))
            raise RecompileError(
                f"steady-state recompiles{tag} (allowed {self.max_new} new "
                f"signature(s) per entry): {detail}")

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.check()
