"""AST lint for the serving loop's trace discipline (rules REX001-REX005).

Each rule guards an invariant a runtime differential would otherwise catch
minutes into a run (the mapping lives in docs/ARCHITECTURE.md):

  REX001  no heavy host-numpy ops inside runtime/ hot-path round bodies
  REX002  no unseeded default_rng / global-RNG calls in trace-affecting code
  REX003  no if/while/bool() on tracer values inside traced functions
  REX004  no set (unordered) iteration feeding trace records or placement
  REX005  jit entry points must declare their static argnames

Suppression syntax (line- or def-level; file-level with disable-file):

    x = np.linalg.norm(v)        # rex: disable=REX001
    # rex: disable-file=REX004

Rules are scoped by repo-relative path substring (see ``_rule_applies``), so
the planted-violation fixture corpus under tests/fixtures/analysis mirrors
the source layout (runtime/, core/, kernels/) to opt into each rule.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path

__all__ = ["Violation", "lint_file", "lint_paths", "RULES"]

RULES = {
    "REX001": "host-numpy heavy op in a runtime hot-path round body",
    "REX002": "unseeded rng in trace-affecting code",
    "REX003": "control flow on a (possibly) traced value",
    "REX004": "iteration over an unordered set feeds downstream state",
    "REX005": "jit entry point does not declare its static argnames",
}


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


# ---------------------------------------------------------------------------
# Rule configuration.  Kept as data at module top so scope changes are diffs
# here, not code changes.
# ---------------------------------------------------------------------------

# REX001: the per-tick dispatch path.  Anything here runs once per round per
# cohort; heavy numpy (reductions, factorizations, sorts) belongs on-device
# or outside the loop.  Cheap marshalling (asarray/stack/full/flatnonzero)
# is explicitly fine — the rule names the expensive offenders.
HOT_PATH_FUNCS = {
    "_round_body", "_skip_round", "_issue_prefetch", "_gather", "_scatter",
    "_plan_round", "rank_round", "rank_advance_round", "advance_round",
}
HEAVY_NP_OPS = {
    "linalg", "argmin", "argmax", "sort", "argsort", "dot", "matmul",
    "einsum", "inner", "outer", "tensordot", "vdot", "exp", "log", "sqrt",
    "percentile", "quantile", "median", "mean", "std", "var", "histogram",
    "cumsum", "cumprod", "corrcoef", "cov", "fft", "unique", "lexsort",
}

# REX002: legacy global-RNG entry points (process-seeded, trace-visible
# nondeterminism).  ``default_rng()`` with no arguments is the other half.
NP_GLOBAL_RNG = {
    "random", "rand", "randn", "randint", "random_sample", "choice",
    "shuffle", "permutation", "normal", "uniform", "poisson", "exponential",
    "standard_normal", "beta", "gamma", "seed",
}
STDLIB_RANDOM_FNS = {
    "random", "randint", "choice", "choices", "shuffle", "uniform",
    "randrange", "sample", "gauss", "normalvariate", "seed", "betavariate",
}

# REX003: functions whose bodies execute under jax tracing.  Decorated jit
# entry points are discovered from their decorators (any file); closures
# dispatched via jit/shard_map are named here per module, mapped to the
# params that arrive as python statics (safe to branch on).
# ``.shape``/``.ndim``/``len()``/``is None`` are always trace-static and
# never flagged.
TRACED_FUNCTION_STATICS: dict[str, dict[str, set[str]]] = {
    # the jit-static SearchPolicy drives all control flow
    "core/policy.py": {
        "spatial_mask": set(),
        "temporal_mask": set(),
        "correlated": set(),
        "replay_sampled_out": {"policy"},
        "admit": {"policy"},
        "advance": {"policy", "horizon"},
    },
    # step bodies both engines dispatch under jit / shard_map
    "runtime/engine.py": {
        "rank_advance_round": {"policy", "k"},
        "rank_advance_round_seg": {"policy", "k"},
        "advance_round": {"policy"},
        "_rank_outcome": {"match_thresh", "n_cams", "topk_rerank"},
    },
    # wrappers run at trace time; kernel bodies run under pallas
    "kernels/reid_topk.py": {
        "reid_topk": {"k", "block_q", "block_g", "interpret"},
        "reid_topk_masked": {"k", "block_q", "block_g", "interpret"},
        "reid_topk_segments": {"k", "block_q", "block_g", "interpret"},
        "_segment_masked_call": {"k", "block_q", "block_g", "interpret"},
        "_reid_kernel": {"k", "block_g", "ng", "g_real"},
        "_reid_masked_kernel": {"k", "block_g", "ng", "g_real"},
        "_merge_topk": {"k"},
        "_mask_padded": set(),
    },
    "kernels/flash_attention.py": {
        "flash_attention": {"causal", "block_q", "block_k", "interpret"},
        "_flash_kernel": {"scale", "causal", "block_q", "block_k", "nk"},
    },
    "kernels/decode_attention.py": {
        "decode_attention": {"block_k", "interpret"},
        "_decode_kernel": {"scale", "block_k", "nk"},
    },
    "kernels/mamba_scan.py": {
        "mamba_scan": {"chunk", "block_d", "interpret"},
        "_scan_kernel": {"chunk", "block_d", "n_state"},
    },
}

# REX005: param names that are search/kernel configuration — python values
# that MUST be jit-static or every distinct value recompiles (or worse,
# traces wrong).  A jit wrapper over a function taking one of these without
# declaring static_argnames/argnums is flagged.
STATIC_VOCAB = {
    "policy", "cfg", "k", "topk", "match_thresh", "scheme", "interpret",
    "causal", "block_q", "block_g", "block_k", "chunk", "block_d",
}

# Calls whose result is a python value even on tracer arguments.
_STATIC_ALWAYS_CALLS = {"len", "isinstance", "hasattr", "ndim", "shape"}
# Calls that are static iff every argument is static.
_STATIC_IF_ARGS_CALLS = {"int", "float", "bool", "min", "max", "abs",
                         "round", "range", "tuple", "str", "repr"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type"}

_SUPPRESS_RE = re.compile(r"#\s*rex:\s*disable(-file)?\s*=\s*([A-Z0-9,\s]+)")


def _rule_applies(rule: str, path: str) -> bool:
    p = path.replace("\\", "/")
    if rule == "REX001":
        return "runtime/" in p
    if rule in ("REX002", "REX004"):
        return any(s in p for s in ("core/", "runtime/", "kernels/"))
    return True      # REX003 scopes by function name, REX005 everywhere


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

def _parse_suppressions(text: str):
    """-> (line -> {rules}, file-level {rules})."""
    by_line: dict[int, set[str]] = {}
    file_level: set[str] = set()
    try:
        toks = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
            if m.group(1):          # disable-file
                file_level |= rules
            else:
                by_line.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        pass
    return by_line, file_level


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------

def _attr_chain(node: ast.AST) -> list[str]:
    """x.a.b -> ["x", "a", "b"]; non-name roots -> []."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


class _Imports(ast.NodeVisitor):
    """Alias maps: which local names refer to numpy / numpy.random /
    stdlib random / jax / functools.partial / the rng factory."""

    def __init__(self):
        self.numpy: set[str] = set()          # import numpy as np
        self.np_random: set[str] = set()      # from numpy import random as r
        self.stdlib_random: set[str] = set()  # import random
        self.default_rng: set[str] = set()    # from numpy.random import default_rng
        self.jax: set[str] = set()            # import jax
        self.jit: set[str] = set()            # from jax import jit
        self.partial: set[str] = set()        # from functools import partial

    def visit_Import(self, node):
        for a in node.names:
            name = a.asname or a.name
            if a.name == "numpy":
                self.numpy.add(name)
            elif a.name == "numpy.random":
                self.np_random.add(name)
            elif a.name == "random":
                self.stdlib_random.add(name)
            elif a.name == "jax":
                self.jax.add(name)

    def visit_ImportFrom(self, node):
        for a in node.names:
            name = a.asname or a.name
            if node.module == "numpy" and a.name == "random":
                self.np_random.add(name)
            elif node.module == "numpy.random" and a.name == "default_rng":
                self.default_rng.add(name)
            elif node.module == "jax" and a.name == "jit":
                self.jit.add(name)
            elif node.module == "functools" and a.name == "partial":
                self.partial.add(name)


def _is_np_call(chain: list[str], imports: _Imports) -> str | None:
    """np.<op>(...) / numpy.<sub>.<op> -> the first attr after the root."""
    if len(chain) >= 2 and chain[0] in imports.numpy:
        return chain[1]
    return None


# ---------------------------------------------------------------------------
# REX003 static-taint evaluation
# ---------------------------------------------------------------------------

class _StaticEval:
    """Is an expression provably a python (trace-static) value inside a
    traced function?  Conservative: unknown constructs are non-static."""

    def __init__(self, static_names: set[str], local_names: set[str]):
        self.static = static_names      # params/locals known static
        self.locals = local_names       # all params + assigned names

    def is_static(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            # non-local names are module globals (constants, functions,
            # jnp/np modules) — python values at trace time
            return node.id in self.static or node.id not in self.locals
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return True             # tracer.shape etc. are python values
            return self.is_static(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_static(node.value) and self.is_static(node.slice)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` are identity tests on the python
            # object, never concretized — always trace-static
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators):
                return True
            return self.is_static(node.left) and \
                all(self.is_static(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand)
        if isinstance(node, ast.BinOp):
            return self.is_static(node.left) and self.is_static(node.right)
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(self.is_static(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self.is_static(node.test) and self.is_static(node.body)
                    and self.is_static(node.orelse))
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            leaf = chain[-1] if chain else None
            if leaf in _STATIC_ALWAYS_CALLS:
                return True             # len()/jnp.ndim() of a tracer: int
            if leaf in _STATIC_IF_ARGS_CALLS:
                return all(self.is_static(a) for a in node.args)
            return False
        return False


def _collect_locals(fn: ast.FunctionDef) -> set[str]:
    names: set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        names.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
    return names


def _decorator_statics(fn: ast.FunctionDef, imports: _Imports) -> set[str] | None:
    """static_argnames declared by a jit decorator, or None if the function
    is not jit-decorated.  Handles @jax.jit, @jit, @partial(jax.jit, ...)
    and @functools.partial(jax.jit, ...)."""
    for dec in fn.decorator_list:
        target, kwargs = _jit_of(dec, imports)
        if target is not None:
            names: set[str] = set()
            for kw in kwargs:
                if kw.arg == "static_argnames":
                    for s in ast.walk(kw.value):
                        if isinstance(s, ast.Constant) and isinstance(s.value, str):
                            names.add(s.value)
            return names
    return None


def _is_jit_ref(node: ast.AST, imports: _Imports) -> bool:
    chain = _attr_chain(node)
    return (chain == ["jax", "jit"]
            or (len(chain) == 2 and chain[0] in imports.jax and chain[1] == "jit")
            or (len(chain) == 1 and chain[0] in imports.jit))


def _jit_of(node: ast.AST, imports: _Imports):
    """If ``node`` is a jit application, return (inner expr or True, kwargs).

    Recognizes ``jax.jit`` (bare decorator), ``jax.jit(f, ...)`` and
    ``partial(jax.jit, [f,] ...)``.  Returns (None, []) otherwise."""
    if _is_jit_ref(node, imports):
        return True, []
    if isinstance(node, ast.Call):
        if _is_jit_ref(node.func, imports):
            inner = node.args[0] if node.args else True
            return inner, node.keywords
        chain = _attr_chain(node.func)
        is_partial = (chain and chain[-1] == "partial"
                      and (chain[0] in imports.partial
                           or chain[0] == "functools"))
        if is_partial and node.args and _is_jit_ref(node.args[0], imports):
            inner = node.args[1] if len(node.args) > 1 else True
            return inner, node.keywords
    return None, []


# ---------------------------------------------------------------------------
# The per-file linter
# ---------------------------------------------------------------------------

class _FileLinter:
    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.imports = _Imports()
        self.imports.visit(self.tree)
        self.suppress_lines, self.suppress_file = _parse_suppressions(text)
        self.violations: list[Violation] = []
        # line span of every function def, for def-level suppression
        self._def_spans: list[tuple[int, int, int]] = []   # (start, end, defline)
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._def_spans.append(
                    (node.lineno, node.end_lineno or node.lineno, node.lineno))

    # -- emission with suppression ----------------------------------------
    def _emit(self, rule: str, line: int, msg: str) -> None:
        if rule in self.suppress_file:
            return
        if rule in self.suppress_lines.get(line, set()):
            return
        for start, end, defline in self._def_spans:
            if start <= line <= end and rule in self.suppress_lines.get(
                    defline, set()):
                return
        self.violations.append(Violation(rule, self.path, line, msg))

    def run(self) -> list[Violation]:
        if _rule_applies("REX001", self.path):
            self._rex001()
        if _rule_applies("REX002", self.path):
            self._rex002()
        self._rex003()
        if _rule_applies("REX004", self.path):
            self._rex004()
        self._rex005()
        return self.violations

    def _functions(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.FunctionDef):
                yield node

    # -- REX001 ------------------------------------------------------------
    def _rex001(self) -> None:
        for fn in self._functions():
            if fn.name not in HOT_PATH_FUNCS:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = _attr_chain(node.func)
                op = _is_np_call(chain, self.imports)
                if op in HEAVY_NP_OPS:
                    self._emit("REX001", node.lineno,
                               f"host numpy `{'.'.join(chain)}` inside "
                               f"hot-path `{fn.name}` — use the jitted "
                               "device path (or hoist out of the round)")

    # -- REX002 ------------------------------------------------------------
    def _rex002(self) -> None:
        imp = self.imports
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if not chain:
                continue
            root, leaf = chain[0], chain[-1]
            is_default_rng = (
                leaf == "default_rng"
                and (root in imp.default_rng
                     or root in imp.np_random
                     or (len(chain) >= 3 and root in imp.numpy
                         and chain[1] == "random")))
            if is_default_rng and not node.args and not node.keywords:
                self._emit("REX002", node.lineno,
                           "`default_rng()` without a seed — trace-affecting "
                           "randomness must derive from an explicit seed")
                continue
            is_np_global = leaf in NP_GLOBAL_RNG and (
                (len(chain) == 2 and root in imp.np_random)
                or (len(chain) >= 3 and root in imp.numpy
                    and chain[1] == "random"))
            if is_np_global:
                self._emit("REX002", node.lineno,
                           f"legacy global-RNG `{'.'.join(chain)}` — use a "
                           "seeded Generator (default_rng(seed))")
                continue
            if (len(chain) == 2 and root in imp.stdlib_random
                    and leaf in STDLIB_RANDOM_FNS):
                self._emit("REX002", node.lineno,
                           f"stdlib `{'.'.join(chain)}` uses the process "
                           "global RNG — use a seeded Generator")

    # -- REX003 ------------------------------------------------------------
    def _rex003(self) -> None:
        path = self.path.replace("\\", "/")
        cfg: dict[str, set[str]] = {}
        for suffix, fns in TRACED_FUNCTION_STATICS.items():
            if path.endswith(suffix):
                cfg.update(fns)
        for fn in self._functions():
            dec_statics = _decorator_statics(fn, self.imports)
            cfg_statics = cfg.get(fn.name)
            if dec_statics is None and cfg_statics is None:
                continue
            statics = (dec_statics or set()) | (cfg_statics or set())
            self._check_traced_fn(fn, statics)

    def _check_traced_fn(self, fn: ast.FunctionDef, statics: set[str]) -> None:
        local_names = _collect_locals(fn)
        known_static = set(statics)
        ev = _StaticEval(known_static, local_names)

        def note_assign(node):
            # sequential taint propagation: a local assigned from a
            # static-only expression is itself static from here on
            targets = []
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                return
            names = [t.id for tgt in targets for t in ast.walk(tgt)
                     if isinstance(t, ast.Name)]
            if ev.is_static(value):
                known_static.update(names)
            else:
                known_static.difference_update(names)

        def flag(test: ast.AST, kind: str):
            if not ev.is_static(test):
                self._emit(
                    "REX003", test.lineno,
                    f"{kind} on a traced value in `{fn.name}` — branch on "
                    "static config/shapes or use jnp.where/lax.cond")

        for node in ast.walk(fn):
            note_assign(node)
        # second pass flags conditions with the full static set (sequential
        # order approximated; reassignment to non-static wins above)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                flag(node.test, "`if`/`while`")
            elif isinstance(node, ast.IfExp):
                flag(node.test, "conditional expression")
            elif isinstance(node, ast.Assert):
                flag(node.test, "`assert`")
            elif isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if chain in (["bool"], ["int"], ["float"]) and node.args:
                    if not all(ev.is_static(a) for a in node.args):
                        self._emit(
                            "REX003", node.lineno,
                            f"`{chain[0]}()` concretizes a traced value in "
                            f"`{fn.name}`")

    # -- REX004 ------------------------------------------------------------
    def _rex004(self) -> None:
        # Set-typed names are tracked PER SCOPE (innermost enclosing
        # function, else module) — a `keys: set` in one method must not
        # taint an unrelated `keys` list in another.
        fns = sorted(self._functions(), key=lambda f: f.lineno)

        def innermost_fn(line: int):
            best = None
            for f in fns:
                if f.lineno <= line <= (f.end_lineno or f.lineno):
                    if best is None or f.lineno >= best.lineno:
                        best = f
            return best

        def set_names_of(scope) -> set[str]:
            names: set[str] = set()
            nodes = ast.walk(scope) if scope is not None else (
                n for n in ast.walk(self.tree) if innermost_fn(
                    getattr(n, "lineno", 0) or 0) is None)
            for node in nodes:
                ann = None
                if isinstance(node, ast.arg):
                    ann = node.annotation
                elif isinstance(node, ast.AnnAssign):
                    ann = node.annotation
                if ann is not None and "set" in ast.unparse(ann).lower():
                    name = node.arg if isinstance(node, ast.arg) else (
                        node.target.id
                        if isinstance(node.target, ast.Name) else None)
                    if name:
                        names.add(name)
                if isinstance(node, ast.Assign) and \
                        self._is_set_expr(node.value):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            names.add(tgt.id)
            return names

        tables: dict[int, set[str]] = {}     # id of scope fn (or 0) -> names

        def names_for(line: int) -> set[str]:
            scope = innermost_fn(line)
            key = id(scope) if scope is not None else 0
            if key not in tables:
                tables[key] = set_names_of(scope)
            return tables[key]

        def iter_is_set(it: ast.AST, set_named: set[str]) -> bool:
            # unwrap enumerate/list/tuple — they preserve the set's
            # (arbitrary) order, so they don't launder it
            if isinstance(it, ast.Call):
                chain = _attr_chain(it.func)
                if chain in (["sorted"],):
                    return False
                if chain in (["enumerate"], ["list"], ["tuple"]) and it.args:
                    return iter_is_set(it.args[0], set_named)
            if self._is_set_expr(it):
                return True
            return isinstance(it, ast.Name) and it.id in set_named

        for node in ast.walk(self.tree):
            iters = []
            if isinstance(node, ast.For):
                iters = [node.iter]
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters = [g.iter for g in node.generators]
            for it in iters:
                if iter_is_set(it, names_for(it.lineno)):
                    self._emit(
                        "REX004", it.lineno,
                        "iterating a set — order is arbitrary; wrap in "
                        "sorted(...) before it feeds traces or placement")

    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            return chain in (["set"], ["frozenset"])
        return False

    # -- REX005 ------------------------------------------------------------
    def _rex005(self) -> None:
        local_fns = {f.name: f for f in self._functions()}

        def check(fn_node: ast.FunctionDef, kwargs, line: int):
            declared = any(kw.arg in ("static_argnames", "static_argnums")
                           for kw in kwargs)
            if declared:
                return
            args = fn_node.args
            params = [a.arg for a in (args.posonlyargs + args.args
                                      + args.kwonlyargs)]
            hits = sorted(set(params) & STATIC_VOCAB)
            if hits:
                self._emit(
                    "REX005", line,
                    f"jit over `{fn_node.name}` takes static-vocabulary "
                    f"param(s) {hits} but declares no "
                    "static_argnames/static_argnums")

        for fn in self._functions():
            for dec in fn.decorator_list:
                inner, kwargs = _jit_of(dec, self.imports)
                if inner is not None:
                    check(fn, kwargs, dec.lineno)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            inner, kwargs = _jit_of(node, self.imports)
            if inner is None or inner is True or not isinstance(inner, ast.Name):
                continue        # jax.jit(shard_map(...)) closures are fine
            fn_node = local_fns.get(inner.id)
            if fn_node is not None:
                check(fn_node, kwargs, node.lineno)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def lint_file(path: str | Path, text: str | None = None,
              virtual_path: str | None = None) -> list[Violation]:
    """Lint one file.  ``virtual_path`` overrides the path used for rule
    scoping and reporting (fixture corpora mirror the source layout)."""
    path = Path(path)
    if text is None:
        text = path.read_text()
    report_as = virtual_path or str(path)
    return _FileLinter(report_as, text).run()


def lint_paths(roots: list[str | Path],
               rel_to: str | Path | None = None) -> list[Violation]:
    """Lint every .py under ``roots`` (files or directories).  Paths are
    reported (and rule-scoped) relative to ``rel_to`` when given."""
    out: list[Violation] = []
    for root in roots:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            rel = str(f.relative_to(rel_to)) if rel_to else str(f)
            out.extend(lint_file(f, virtual_path=rel))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))
