"""The registered jit entry points of the serving loop.

One place that answers "what compiles?": every jitted callable the engines
dispatch per round, each paired with a builder for tiny, fully-deterministic
example arguments.  The jaxpr auditor traces each entry through its REAL
jit wrapper (statics and all) and walks the resulting ClosedJaxpr; the
RecompileGuard snapshots the same wrappers' compile caches.

Example args are deliberately minute (C=4 cameras, Q=8 queries, G=24
gallery rows) — tracing is abstract, so sizes only shape the jaxpr, and the
audit must stay cheap enough to run as a blocking CI step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

__all__ = ["JitEntry", "jit_entry_fns", "entries"]


@dataclasses.dataclass(frozen=True)
class JitEntry:
    name: str
    fn: Any                                   # jitted: .trace/._cache_size
    example: Callable[[], tuple[tuple, dict]]  # -> (args, kwargs)


def _tiny_model(C: int = 4, NB: int = 8):
    from repro.core.profiler import build_model
    rng = np.random.default_rng(7)
    E, hops = 6, 5
    ent = np.repeat(np.arange(E), hops)
    cam = rng.integers(0, C, E * hops)
    t_in = np.concatenate([np.sort(rng.integers(0, 64, hops))
                           for _ in range(E)])
    t_out = t_in + rng.integers(1, 4, E * hops)
    return build_model(ent, cam, t_in, t_out, C, n_bins=NB)


def _example_world(Q: int = 8, G: int = 24, D: int = 16, C: int = 4):
    """Deterministic batched example state shared by every entry builder."""
    import jax.numpy as jnp
    from repro.core.policy import PhaseState, SearchPolicy, phase_windows

    model = _tiny_model(C=C)
    policy = SearchPolicy()
    windows = phase_windows(model, policy)
    rng = np.random.default_rng(11)
    state = PhaseState(
        f_q=jnp.asarray(rng.integers(0, 8, Q), jnp.int32),
        c_q=jnp.asarray(rng.integers(0, C, Q), jnp.int32),
        f_curr=jnp.asarray(rng.integers(8, 16, Q), jnp.int32),
        phase=jnp.ones(Q, jnp.int32),
        live_f=jnp.full(Q, 16.0, jnp.float32),
        done=jnp.zeros(Q, bool),
    )
    q_feat = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, (Q, C)), bool)
    gal = jnp.asarray(rng.normal(size=(G, D)), jnp.float32)
    gal_cam = jnp.asarray(rng.integers(0, C, G), jnp.int32)
    gal_frame = jnp.asarray(np.repeat(state.f_curr, G // Q + 1)[:G], jnp.int32)
    # the consolidation plane's round-scoped relabeling: distinct content
    # frames -> compact segment ids (exactly what RoundPlan builds)
    segs = {f: s for s, f in
            enumerate(sorted({int(x) for x in np.asarray(state.f_curr)}))}
    q_seg = jnp.asarray([segs[int(x)] for x in np.asarray(state.f_curr)],
                        jnp.int32)
    gal_seg = jnp.asarray([segs[int(x)] for x in np.asarray(gal_frame)],
                          jnp.int32)
    # the sub-frame spatial admission plane at a tiny T=2 grid: a tile-
    # carrying model clone, the fused (camera, tile) admission, and
    # per-row fused cell tags (one unlabeled row exercises the -1 path)
    import dataclasses as _dc
    T = 2
    TT = T * T
    model_tiles = _dc.replace(
        model, tile_admit=jnp.asarray(rng.integers(0, 2, (C, C, TT)), bool),
        tile_grid=T, tile_learned=True)
    mask_ct = jnp.asarray(rng.integers(0, 2, (Q, C * TT)), bool)
    # per-query last-matched tiles for the learned self-follow column; one
    # -1 row exercises the no-match-yet (admit-everything) path
    tile_q = jnp.asarray(
        np.where(np.arange(Q) % 3 == 0, -1, rng.integers(0, TT, Q)),
        jnp.int32)
    gal_ct = jnp.asarray(
        np.where(np.arange(G) == G - 1, -1,
                 np.asarray(gal_cam) * TT + rng.integers(0, TT, G)),
        jnp.int32)
    return dict(model=model, policy=policy, windows=windows, state=state,
                q_feat=q_feat, mask=mask, gal=gal, gal_cam=gal_cam,
                gal_frame=gal_frame, q_seg=q_seg, gal_seg=gal_seg,
                model_tiles=model_tiles, mask_ct=mask_ct, gal_ct=gal_ct,
                tile_q=tile_q, n_cams=C)


def jit_entry_fns() -> dict[str, Any]:
    """name -> module-level jitted callable, for RecompileGuard snapshots.
    (The fleet's per-mesh shard_map jits are added per engine — see
    ``RecompileGuard.for_engine``.)"""
    from repro.kernels import ops as kernel_ops
    from repro.runtime import engine as _engine
    return {
        "policy.admit": _engine._admit_jit,
        "policy.admit_tiles": _engine._admit_tiles_jit,
        "policy.advance": _engine._advance_round_jit,
        "rank_round": _engine.rank_round,
        "rank_round_seg": _engine.rank_round_seg,
        "rank_round_tiles": _engine.rank_round_tiles,
        "rank_advance_round": _engine._rank_advance_jit,
        "rank_advance_round_seg": _engine._rank_advance_seg_jit,
        "rank_advance_round_tiles": _engine._rank_advance_tiles_jit,
        "reid_topk": kernel_ops.reid_topk,
        "reid_topk_masked": kernel_ops.reid_topk_masked,
        "reid_topk_segments": kernel_ops.reid_topk_segments,
        "reid_topk_tiles": kernel_ops.reid_topk_tiles,
    }


def entries(include_fleet: bool = True) -> list[JitEntry]:
    """Every registered jit entry with example args, for the jaxpr audit.

    ``include_fleet`` adds the shard_map step bodies on a 1-device mesh
    (tracing needs no fleet, just the mesh the jaxpr closes over)."""
    from repro.kernels import ops as kernel_ops
    from repro.runtime import engine as _engine

    w = _example_world()
    fns = jit_entry_fns()
    out = [
        JitEntry("policy.admit", fns["policy.admit"],
                 lambda: ((w["model"], w["policy"], w["state"], None), {})),
        JitEntry("policy.advance", fns["policy.advance"],
                 lambda: ((w["policy"], w["windows"], w["state"]), {})),
        JitEntry("rank_round", fns["rank_round"],
                 lambda: ((w["q_feat"], w["state"].f_curr, w["mask"],
                           w["gal"], w["gal_cam"], w["gal_frame"],
                           w["policy"].match_thresh, 2), {})),
        JitEntry("rank_round_seg", fns["rank_round_seg"],
                 lambda: ((w["q_feat"], w["q_seg"], w["mask"], w["gal"],
                           w["gal_cam"], w["gal_frame"], w["gal_seg"],
                           w["policy"].match_thresh, 2), {})),
        JitEntry("rank_advance_round", fns["rank_advance_round"],
                 lambda: ((w["policy"], w["windows"], w["state"], w["q_feat"],
                           w["mask"], w["gal"], w["gal_cam"], w["gal_frame"]),
                          dict(k=1))),
        JitEntry("rank_advance_round_seg", fns["rank_advance_round_seg"],
                 lambda: ((w["policy"], w["windows"], w["state"], w["q_feat"],
                           w["q_seg"], w["mask"], w["gal"], w["gal_cam"],
                           w["gal_frame"], w["gal_seg"]), dict(k=1))),
        JitEntry("reid_topk", fns["reid_topk"],
                 lambda: ((w["q_feat"], w["gal"], 2), dict(interpret=True))),
        JitEntry("reid_topk_masked", fns["reid_topk_masked"],
                 lambda: ((w["q_feat"], w["state"].f_curr, w["mask"],
                           w["gal"], w["gal_cam"], w["gal_frame"], 2),
                          dict(interpret=True))),
        JitEntry("reid_topk_segments", fns["reid_topk_segments"],
                 lambda: ((w["q_feat"], w["q_seg"], w["mask"], w["gal"],
                           w["gal_cam"], w["gal_seg"], 2),
                          dict(interpret=True))),
        JitEntry("policy.admit_tiles", fns["policy.admit_tiles"],
                 lambda: ((w["model_tiles"], w["policy"], w["state"], None,
                           w["tile_q"]), {})),
        JitEntry("rank_round_tiles", fns["rank_round_tiles"],
                 lambda: ((w["q_feat"], w["q_seg"], w["mask_ct"], w["gal"],
                           w["gal_ct"], w["gal_cam"], w["gal_frame"],
                           w["gal_seg"], w["policy"].match_thresh, 2,
                           w["n_cams"]), {})),
        JitEntry("rank_advance_round_tiles", fns["rank_advance_round_tiles"],
                 lambda: ((w["policy"], w["windows"], w["state"], w["q_feat"],
                           w["q_seg"], w["mask_ct"], w["gal"], w["gal_ct"],
                           w["gal_cam"], w["gal_frame"], w["gal_seg"]),
                          dict(k=1, n_cams=w["n_cams"]))),
        JitEntry("reid_topk_tiles", fns["reid_topk_tiles"],
                 lambda: ((w["q_feat"], w["q_seg"], w["mask_ct"], w["gal"],
                           w["gal_ct"], w["gal_seg"], 2),
                          dict(interpret=True))),
    ]
    if include_fleet:
        import jax
        from repro.runtime.cluster import ElasticMesh
        from repro.runtime.fleet import make_sharded_step_fns
        mesh = ElasticMesh(model_parallel=1).make_mesh([jax.devices()[0]])
        (f_admit, f_rank, f_rank_seg, f_advance, f_admit_tiles,
         f_rank_tiles) = make_sharded_step_fns(mesh, w["policy"], topk=1,
                                               n_cams=w["n_cams"])
        out += [
            JitEntry("fleet.admit@shard_map", f_admit,
                     lambda: ((w["model"], w["state"], None), {})),
            JitEntry("fleet.rank_advance@shard_map", f_rank,
                     lambda: ((w["windows"], w["state"], w["q_feat"],
                               w["mask"], w["gal"], w["gal_cam"],
                               w["gal_frame"]), {})),
            JitEntry("fleet.rank_advance_seg@shard_map", f_rank_seg,
                     lambda: ((w["windows"], w["state"], w["q_feat"],
                               w["q_seg"], w["mask"], w["gal"], w["gal_cam"],
                               w["gal_frame"], w["gal_seg"]), {})),
            JitEntry("fleet.advance@shard_map", f_advance,
                     lambda: ((w["windows"], w["state"]), {})),
            JitEntry("fleet.admit_tiles@shard_map", f_admit_tiles,
                     lambda: ((w["model_tiles"], w["state"], None,
                               w["tile_q"]), {})),
            JitEntry("fleet.rank_advance_tiles@shard_map", f_rank_tiles,
                     lambda: ((w["windows"], w["state"], w["q_feat"],
                               w["q_seg"], w["mask_ct"], w["gal"],
                               w["gal_ct"], w["gal_cam"], w["gal_frame"],
                               w["gal_seg"]), {})),
        ]
    return out
