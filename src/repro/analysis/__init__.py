"""The static invariant plane (scripts/check_invariants.py, CI hard gate).

Three layers, each guarding a class of regression the runtime differentials
(tracker<->engine, single<->fleet, fault-schedule trace identity) would only
catch minutes into a run:

  lint         AST rules REX001-REX005 over the repo source (host work in
               hot round bodies, unseeded rngs, tracer-dependent control
               flow, unordered iteration feeding traces, undeclared jit
               statics).
  jaxpr_audit  walks the ClosedJaxpr of every registered jit entry point
               for forbidden primitives / f64 / weak-type / dynamic shapes,
               and exports RecompileGuard (steady-state compile-count
               assertions for tests and benchmarks).
  kernel_audit Pallas grid/BlockSpec bounds proofs plus the masked-slot
               (NEG_INF, -1) sentinel convention probes.

Submodules are imported lazily: ``repro.runtime.transport`` imports
``repro.analysis.sanitize`` (the REPRO_SANITIZE=1 switch), and an eager
``from .jaxpr_audit import *`` here would close an import cycle through
``repro.runtime.engine``.
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("jaxpr_audit", "kernel_audit", "lint", "registry", "sanitize")
_EXPORTS = {
    "RecompileGuard": "jaxpr_audit",
    "RecompileError": "jaxpr_audit",
    "audit_jaxprs": "jaxpr_audit",
    "audit_kernels": "kernel_audit",
    "lint_paths": "lint",
    "lint_file": "lint",
    "Violation": "lint",
}

__all__ = list(_SUBMODULES) + list(_EXPORTS)


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    if name in _EXPORTS:
        mod = importlib.import_module(f"{__name__}.{_EXPORTS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
