"""rexcam facade — the stable control-plane API every consumer programs to.

    from repro import api as rexcam

    model  = rexcam.profile(history_visits)                  # offline §6
    result = rexcam.track(model, visits, gallery, feats,     # batched Alg. 1
                          q_vids, gt_vids,
                          policy=rexcam.SearchPolicy(s_thresh=.05))
    engine = rexcam.serve(model, embed_fn,                   # live engine
                          policy=rexcam.SearchPolicy())

All three run the SAME admission/phase machinery from
``repro.core.policy`` — one ``SearchPolicy``, one ``admit``, one phase
machine — so offline experiments, benchmarks and the live serving plane
cannot drift apart.  (``docs/ARCHITECTURE.md`` maps every paper section to
the module that implements it.)
"""
from __future__ import annotations

from typing import Callable

from repro.core.correlation import SpatioTemporalModel
from repro.core.policy import (PhaseState, SearchPolicy, admit, advance,  # noqa: F401
                               phase_windows)
from repro.core.profiler import build_model
from repro.core.simulate import Visits
from repro.core.tracker import (TrackResult, make_queries, track_queries,  # noqa: F401
                                trace_queries)
from repro.runtime.engine import EngineConfig, ServingEngine
from repro.runtime.fleet import ShardedServingEngine
from repro.runtime.recal import (RecalibrationController,  # noqa: F401
                                 RecalibrationPolicy, visits_window_source)
from repro.runtime.transport import (FakeRpcTransport, FaultProfile,  # noqa: F401
                                     InProcTransport, Transport)
from repro.analysis import sanitize as _sanitize

# REPRO_SANITIZE=1 arms the runtime sanitizers for everything built through
# this facade: jax_debug_nans (NaN fails at the producing op, not 40 rounds
# later in a ranking) + the transport plane's dead-peer callback reentrancy
# assertions.  Latched once at import; sanitize.enable()/disable() toggles
# programmatically.
_sanitize.maybe_enable_from_env()


def profile(visits: Visits, *, time_limit: int | None = None,
            n_bins: int = 256, bin_width: int = 1,
            sample_every: int = 1, epoch: int = 0, tile_grid: int = 0,
            tile_keep: float = 1.0) -> SpatioTemporalModel:
    """Offline profiling (paper §6): historical visits -> spatio-temporal
    model M.

    Keywords:
      time_limit=    profile only visits *starting* before this step (the
                     paper's §8.4 prefix-partition methodology); None
                     profiles the whole table.
      n_bins=        travel-time histogram bins per camera pair (CDF
                     resolution; a model can only be hot-swapped for one
                     with the SAME n_bins).
      bin_width=     steps per histogram bin (coarser bins trade temporal
                     precision for memory at city scale).
      sample_every=  emulate frame-sampled MTMC labeling: keep only visits a
                     multiple-of-k tick intersects and quantize their
                     timestamps (§8.4's cheaper-profiling degradation).
      epoch=         model version stamp (0 = offline profile; the
                     recalibration loop bumps it on every hot-swap).
      tile_grid=     T > 0 additionally learns per (src, dst) camera-pair
                     entry-region masks over a T x T sub-frame tile grid
                     (CrossRoI-style spatial admission) from the visits'
                     normalized ``tile_xy`` positions — serving with
                     ``serve(..., tile_grid=T)`` then admits only those
                     tiles.  Requires ``visits.tile_xy``; 0 (default) skips
                     the spatial plane entirely.
      tile_keep=     fraction of each pair's observed entry mass the learned
                     mask must cover before the 3x3 dilation halo (1.0 keeps
                     every observed tile — the recall-safe default).
    """
    return build_model(visits.ent, visits.cam, visits.t_in, visits.t_out,
                       visits.n_cams, n_bins=n_bins, bin_width=bin_width,
                       sample_every=sample_every, time_limit=time_limit,
                       epoch=epoch, tile_xy=visits.tile_xy,
                       tile_grid=tile_grid, tile_keep=tile_keep)


def track(model: SpatioTemporalModel, visits: Visits, gallery, feats,
          q_vids, gt_vids, policy: SearchPolicy = SearchPolicy(),
          geo_adj=None) -> TrackResult:
    """Batched Algorithm-1 tracking of all queries under one policy.

    Positional: the profiled model M, the live ``Visits`` table, the dense
    per-(camera, step) detection ``gallery`` (``build_gallery``), per-visit
    re-id features, and the query/ground-truth visit ids
    (``make_queries``).

    Keywords:
      policy=   the shared ``SearchPolicy`` (scheme, thresholds, replay
                settings) — the same object the serving engine takes.
      geo_adj=  (C, C) bool proximity mask for the geo baseline scheme;
                None degrades geo to all-camera (the tracker's default).
    """
    return track_queries(model, visits, gallery, feats, q_vids, gt_vids,
                         policy, geo_adj=geo_adj)


def serve(model: SpatioTemporalModel, embed_fn: Callable,
          policy: SearchPolicy = SearchPolicy(), *, max_batch: int = 256,
          retention: int = 600, geo_adj=None, shards: int | None = None,
          devices=None, gallery: str = "auto", topk: int = 1,
          transport=None, prefetch: bool = False, consolidate: bool = True,
          tile_grid: int = 0, topk_rerank: bool = False,
          recalibrate=None, visit_source=None) -> ServingEngine:
    """Live serving engine driving the same vectorized admission plane.

    Keywords:
      max_batch=     embedding micro-batch cap per round.
      retention=     FrameStore ring-buffer horizon in steps (§5.3's "last
                     few minutes"; replay past it surfaces replay_misses).
      geo_adj=       (C, C) bool proximity mask for the geo baseline.
      shards=        None -> the single-process engine; k -> a
                     ``ShardedServingEngine`` whose query axis is
                     shard_map-partitioned over k devices of the local mesh
                     — trace-identical to the single engine, pinned by the
                     differential harness in tests/test_sharded_engine.py.
      devices=       explicit device list for the fleet (overrides shards'
                     "first k of jax.devices()").
      gallery=       the embedding plane behind the engine(s): "auto" (a
                     per-engine ``LocalGalleryStore`` for the single engine,
                     the fleet-shared ``ShardedGalleryStore`` for the
                     fleet), "local" (force the replicated-baseline host
                     cache) or "sharded" (fleet only: camera-hash owner
                     shards over the data axis).
      topk=          surface the k best (value, camera, frame) candidate
                     bands per query round in trace records (§5.2
                     confidence bands); the argmax match path is band 0 and
                     is unchanged by k > 1.
      transport=     the gallery fetch plane (``repro.runtime.transport``):
                     None (default) keeps direct zero-copy reads; "inproc"
                     names the same behavior explicitly through the
                     ``Transport`` contract (counters tick); a ``Transport``
                     instance — e.g. ``FakeRpcTransport`` with per-peer
                     injected latency/jitter/drop/reorder and
                     timeout/retry/backoff — routes every owner-shard block
                     fetch through it.  Requires the sharded fleet gallery
                     (shards= with gallery "auto"/"sharded").  A peer whose
                     retry budget exhausts fires the dead-peer signal: the
                     gallery re-homes immediately and the fleet scales down
                     at the end of the tick.
      prefetch=      double-buffered speculative fetch: at the end of round
                     N the engine issues async fetches for round N+1's
                     predicted admitted blocks so transport latency hides
                     behind compute; misspeculation falls back to the
                     blocking fetch (exactly accounted as prefetch_wasted).
                     Never changes the trace — only when blocks arrive.
      consolidate=   cross-query object-level consolidation (default True):
                     each round builds one fleet-global ``RoundPlan`` keyed
                     by unique admitted (camera, frame) and ranks EVERY
                     live query in a single segment-ID kernel call
                     (``reid_topk_segments``), so per-round embed/rank cost
                     scales with unique frames, not live queries.  False
                     keeps the per-frame reference ranking path; the two
                     are trace-identical (pinned by the consolidation
                     differential) — the knob only exists as the
                     reference baseline and an escape hatch.
      tile_grid=     sub-frame spatial admission (default 0 = off): T > 0
                     refines camera admission to a T x T tile grid — each
                     round ranks through the tile-masked ``reid_topk_tiles``
                     kernel, scoring only gallery detections inside the
                     model's learned per-(src, dst) entry-region tiles
                     (``profile(..., tile_grid=T)``).  A model without tile
                     data serves all-tiles-admitted, which is
                     trace-identical to camera-granular serving (pinned by
                     the tile differential).  Tile mode makes per-detection
                     tile labels MANDATORY at ingest:
                     ``engine.ingest(frames_by_cam, tiles_by_cam)``.
      topk_rerank=   §5.2 top-k confidence re-ranking (default False): the
                     candidate bands that pass the match threshold vote by
                     summed score per camera and the match re-anchors to the
                     winning camera's best band.  Bit-identical to the
                     argmax path at topk=1 (pinned by the k=1 equivalence
                     regression).
      recalibrate=   close the §6 drift loop: True (default trigger knobs)
                     or a ``RecalibrationPolicy`` attaches a
                     ``RecalibrationController`` that polls the engine's
                     live rescue matrix and hot-swaps a re-profiled M
                     (epoch-bumped, atomic between rounds — on the fleet,
                     re-replicated onto every shard) when drift trips the
                     hysteresis trigger.  None (default) serves the frozen
                     model forever.
      visit_source=  where recalibration re-profiles from: a callable
                     ``(lo, hi) -> (ent, cam, t_in, t_out)`` over the
                     recent window — ``visits_window_source(visits)`` wraps
                     a ground-truth table (the "re-run the MTMC profiler"
                     deployment recipe).  None falls back to the engine's
                     own confirmed-sighting log (``match_log_source``).
                     Only meaningful with recalibrate=.
    """
    if transport == "inproc":
        transport = InProcTransport()
    elif isinstance(transport, str):
        raise ValueError(f"unknown transport {transport!r} (expected None, "
                         f"'inproc' or a runtime.transport.Transport)")
    if transport is not None and shards is None and devices is None:
        raise ValueError("transport= requires the sharded fleet "
                         "(serve(..., shards=k)): the single engine's local "
                         "gallery has no remote owners to fetch from")
    cfg = EngineConfig(policy=policy, max_batch=max_batch,
                       retention=retention, gallery=gallery, topk=topk,
                       transport=transport, prefetch=prefetch,
                       consolidate=consolidate, tile_grid=tile_grid,
                       topk_rerank=topk_rerank)
    if shards is not None or devices is not None:
        eng = ShardedServingEngine(model, embed_fn, cfg, geo_adj=geo_adj,
                                   shards=shards, devices=devices)
    else:
        eng = ServingEngine(model, embed_fn, cfg, geo_adj=geo_adj)
    if recalibrate is not None and recalibrate is not False:
        rp = RecalibrationPolicy() if recalibrate is True else recalibrate
        if not isinstance(rp, RecalibrationPolicy):
            raise TypeError(f"recalibrate= takes True or a "
                            f"RecalibrationPolicy, got {recalibrate!r}")
        eng.recal = RecalibrationController(eng, visit_source, rp)
    elif visit_source is not None:
        raise ValueError("visit_source= given without recalibrate= — pass "
                         "recalibrate=True (or a RecalibrationPolicy) to "
                         "attach the recalibration loop")
    return eng
