"""rexcam facade — the stable control-plane API every consumer programs to.

    from repro import api as rexcam

    model  = rexcam.profile(history_visits)                  # offline §6
    result = rexcam.track(model, visits, gallery, feats,     # batched Alg. 1
                          q_vids, gt_vids,
                          policy=rexcam.SearchPolicy(s_thresh=.05))
    engine = rexcam.serve(model, embed_fn,                   # live engine
                          policy=rexcam.SearchPolicy())

All three run the SAME admission/phase machinery from
``repro.core.policy`` — one ``SearchPolicy``, one ``admit``, one phase
machine — so offline experiments, benchmarks and the live serving plane
cannot drift apart.
"""
from __future__ import annotations

from typing import Callable

from repro.core.correlation import SpatioTemporalModel
from repro.core.policy import (PhaseState, SearchPolicy, admit, advance,  # noqa: F401
                               phase_windows)
from repro.core.profiler import build_model
from repro.core.simulate import Visits
from repro.core.tracker import (TrackResult, make_queries, track_queries,  # noqa: F401
                                trace_queries)
from repro.runtime.engine import EngineConfig, ServingEngine
from repro.runtime.fleet import ShardedServingEngine


def profile(visits: Visits, *, time_limit: int | None = None,
            n_bins: int = 256, bin_width: int = 1,
            sample_every: int = 1) -> SpatioTemporalModel:
    """Offline profiling (paper §6): historical visits -> spatio-temporal
    model M.  ``time_limit`` restricts profiling to the historical partition
    (visits *starting* at or after it are excluded)."""
    return build_model(visits.ent, visits.cam, visits.t_in, visits.t_out,
                       visits.n_cams, n_bins=n_bins, bin_width=bin_width,
                       sample_every=sample_every, time_limit=time_limit)


def track(model: SpatioTemporalModel, visits: Visits, gallery, feats,
          q_vids, gt_vids, policy: SearchPolicy = SearchPolicy(),
          geo_adj=None) -> TrackResult:
    """Batched Algorithm-1 tracking of all queries under one policy."""
    return track_queries(model, visits, gallery, feats, q_vids, gt_vids,
                         policy, geo_adj=geo_adj)


def serve(model: SpatioTemporalModel, embed_fn: Callable,
          policy: SearchPolicy = SearchPolicy(), *, max_batch: int = 256,
          retention: int = 600, geo_adj=None, shards: int | None = None,
          devices=None, gallery: str = "auto",
          topk: int = 1) -> ServingEngine:
    """Live serving engine driving the same vectorized admission plane.

    ``shards=None`` returns the single-process engine; ``shards=k`` (or an
    explicit ``devices`` list) returns a ``ShardedServingEngine`` whose
    query axis is shard_map-partitioned over k devices of the local mesh —
    trace-identical to the single engine, pinned by the differential
    harness in tests/test_sharded_engine.py.

    ``gallery`` selects the embedding plane behind the engine(s):
    ``"auto"`` (a per-engine ``LocalGalleryStore`` for the single engine,
    the fleet-shared ``ShardedGalleryStore`` for the fleet), ``"local"``
    (force the replicated-baseline host cache) or ``"sharded"`` (fleet
    only: camera-hash owner shards over the data axis).

    ``topk`` surfaces the k best (value, camera, frame) candidate bands per
    query round in the trace records (§5.2 confidence bands); the argmax
    match path is band 0 and is unchanged by k > 1."""
    cfg = EngineConfig(policy=policy, max_batch=max_batch,
                       retention=retention, gallery=gallery, topk=topk)
    if shards is not None or devices is not None:
        return ShardedServingEngine(model, embed_fn, cfg, geo_adj=geo_adj,
                                    shards=shards, devices=devices)
    return ServingEngine(model, embed_fn, cfg, geo_adj=geo_adj)
