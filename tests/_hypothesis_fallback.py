"""Minimal deterministic stand-in for ``hypothesis`` (not pip-installable here).

Implements just the surface the test suite uses — ``given``, ``settings`` and
the ``integers / lists / booleans / sampled_from / composite`` strategies —
with a fixed-seed RNG so runs are reproducible.  When the real hypothesis is
importable the test modules use it instead; this shim only keeps the property
tests exercising many generated examples on minimal images.
"""
from __future__ import annotations

import functools
import random
import types

_DEFAULT_EXAMPLES = 20


class Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq) -> Strategy:
    items = list(seq)
    return Strategy(lambda rng: items[rng.randrange(len(items))])


def lists(elements: Strategy, *, min_size: int = 0, max_size: int = 10) -> Strategy:
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]
    return Strategy(sample)


def composite(fn):
    """``@st.composite`` — fn(draw, *args) becomes a strategy factory."""
    def factory(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.sample(rng), *args, **kwargs)
        return Strategy(sample)
    return factory


def given(*strategies: Strategy):
    def deco(fn):
        # NOT functools.wraps: the wrapper must expose a zero-arg signature or
        # pytest tries to resolve the drawn parameters as fixtures.
        def wrapper():
            rng = random.Random(0xC0FFEE)
            for _ in range(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)):
                fn(*[s.sample(rng) for s in strategies])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = _DEFAULT_EXAMPLES
        # pytest plugins (anyio) unwrap property tests via .hypothesis.inner_test
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


st = types.SimpleNamespace(
    integers=integers, booleans=booleans, sampled_from=sampled_from,
    lists=lists, composite=composite,
)
