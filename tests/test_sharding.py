"""Distribution tests — run in subprocesses so the host-device-count flag
never leaks into the other tests' single-device jax runtime."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_sub(code: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_moe_ep_matches_local_dispatch():
    """shard_map EP dispatch == single-device dispatch (same routing math)."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_block, init_moe
        from repro.parallel.sharding import AxisRules, SINGLE_POD_RULES, mesh_context

        cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b"),
                                  capacity_factor=8.0)
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(4, 2)
        key = jax.random.PRNGKey(0)
        p, _ = init_moe(key, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                              jnp.float32)

        out_local, aux_l, _ = moe_block(p, x, cfg)   # no mesh context -> local

        rules = SINGLE_POD_RULES
        with mesh_context(mesh, rules):
            f = jax.jit(lambda p, x: moe_block(p, x, cfg),
                        in_shardings=(
                            {"router": NamedSharding(mesh, P()),
                             "wi": NamedSharding(mesh, P("data", None, "model")),
                             "wg": NamedSharding(mesh, P("data", None, "model")),
                             "wo": NamedSharding(mesh, P("data", "model", None))},
                            NamedSharding(mesh, P("data", None, None))))
            out_ep, aux_e, _ = f(p, x)
        err = float(jnp.abs(out_local - out_ep).max())
        rel = err / float(jnp.abs(out_local).max())
        assert rel < 2e-2, (err, rel)
        print("moe ep ok", rel)
    """)


def test_tiny_mesh_train_step_executes():
    """A reduced config's train step runs END-TO-END on a 4x2 mesh."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.shapes import ShapeSpec
        from repro.launch.steps import build_train_step
        from repro.models import init_params
        from repro.optim import init_opt_state
        from repro.parallel.sharding import SINGLE_POD_RULES, mesh_context

        cfg = get_smoke_config("yi_6b")
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(4, 2)
        shape = ShapeSpec("t", "train", 64, 8)
        with mesh_context(mesh, SINGLE_POD_RULES):
            step, _ = build_train_step(cfg, mesh, SINGLE_POD_RULES, shape)
            params = init_params(cfg, jax.random.PRNGKey(0))
            opt = init_opt_state(params)
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                                  (8, 64), 0, cfg.vocab_size)}
            p1, o1, m1 = step(params, opt, batch)
            p2, o2, m2 = step(p1, o1, batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert np.isfinite(l1) and np.isfinite(l2) and l2 < l1, (l1, l2)
        print("mesh train ok", l1, l2)
    """)


def test_sharded_equals_single_device():
    """Forward pass on the 4x2 mesh == single-device forward (same params)."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models import forward, init_params, param_logical_axes
        from repro.parallel.sharding import (SINGLE_POD_RULES, logical_to_spec,
                                             mesh_context)

        for arch in ["deepseek_7b", "zamba2_2p7b", "falcon_mamba_7b"]:
            # fp32 compute: isolates sharding-logic errors from bf16
            # reduction-order noise
            cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
            params = init_params(cfg, jax.random.PRNGKey(0))
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                                  (4, 32), 0, cfg.vocab_size)}
            ref, _ = forward(params, batch, cfg)

            from repro.launch.mesh import make_test_mesh
            mesh = make_test_mesh(4, 2)
            rules = SINGLE_POD_RULES
            def is_ax(x):
                return isinstance(x, tuple) and all(
                    a is None or isinstance(a, str) for a in x)
            p_sh = jax.tree.map(
                lambda ax: NamedSharding(mesh, logical_to_spec(ax, rules)),
                param_logical_axes(cfg), is_leaf=is_ax)
            with mesh_context(mesh, rules):
                f = jax.jit(lambda p, b: forward(p, b, cfg)[0],
                            in_shardings=(p_sh, NamedSharding(mesh, P("data", None))))
                out = f(params, batch)
            err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
            scale = float(jnp.abs(ref).max())
            assert err / scale < 1e-4, (arch, err, scale)
            print(arch, "sharded==single ok", err)
    """)


def test_dryrun_cell_tiny_mesh_multipod():
    """The dry-run path itself on a (2,2,2) multipod test mesh (lower+compile)."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs import get_smoke_config
        from repro.launch.shapes import ShapeSpec
        from repro.launch.steps import build_decode_step, build_train_step
        from repro.parallel.sharding import MULTI_POD_RULES, mesh_context

        cfg = get_smoke_config("qwen2_vl_72b")
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 2, pod=2)
        with mesh_context(mesh, MULTI_POD_RULES):
            step, abstract = build_train_step(cfg, mesh, MULTI_POD_RULES,
                                              ShapeSpec("t", "train", 64, 8))
            compiled = step.lower(*abstract).compile()
            assert compiled.memory_analysis() is not None
            step2, abstract2 = build_decode_step(cfg, mesh, MULTI_POD_RULES,
                                                 ShapeSpec("d", "decode", 128, 8))
            compiled2 = step2.lower(*abstract2).compile()
        print("multipod tiny-mesh dryrun ok")
    """)
