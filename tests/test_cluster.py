"""runtime.cluster: heartbeat liveness, EWMA seeding, straggler quarantine,
and ElasticMesh scale-down — the fleet's host-side control plane."""
import numpy as np
import pytest

from repro.runtime.cluster import ElasticMesh, HeartbeatMonitor


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------

def test_ewma_zero_latency_is_a_real_sample():
    """Regression: a legitimate 0.0 first sample must seed the EWMA — the
    old ``st.latency_ewma or tick_latency`` treated it as 'unset' and
    re-seeded on the next report (10.0 instead of 0.3 * 10)."""
    clk = FakeClock()
    mon = HeartbeatMonitor(["w0"], ewma=0.3, clock=clk)
    mon.heartbeat("w0", tick_latency=0.0)
    assert mon.workers["w0"].latency_ewma == 0.0
    mon.heartbeat("w0", tick_latency=10.0)
    assert mon.workers["w0"].latency_ewma == pytest.approx(3.0)


def test_ewma_first_sample_seeds_then_smooths():
    clk = FakeClock()
    mon = HeartbeatMonitor(["w0"], ewma=0.5, clock=clk)
    assert mon.workers["w0"].latency_ewma is None     # no sample yet
    mon.heartbeat("w0")                               # liveness-only beat
    assert mon.workers["w0"].latency_ewma is None
    mon.heartbeat("w0", tick_latency=4.0)
    assert mon.workers["w0"].latency_ewma == 4.0      # explicit seed
    mon.heartbeat("w0", tick_latency=8.0)
    assert mon.workers["w0"].latency_ewma == pytest.approx(6.0)


def test_dead_worker_detection_fake_clock():
    clk = FakeClock()
    mon = HeartbeatMonitor(["w0", "w1", "w2"], timeout=10.0, clock=clk)
    clk.now = 8.0
    mon.heartbeat("w0")
    mon.heartbeat("w1")
    clk.now = 15.0                     # w2's last beat was at t=0
    assert mon.dead() == ["w2"]
    assert sorted(mon.active()) == ["w0", "w1"]
    clk.now = 30.0                     # now everyone is silent too long
    assert sorted(mon.dead()) == ["w0", "w1", "w2"]
    assert mon.active() == []


def test_straggler_flagged_at_k_times_median():
    clk = FakeClock()
    mon = HeartbeatMonitor(["w0", "w1", "w2", "w3"], straggler_factor=3.0,
                           ewma=1.0, clock=clk)
    for w in ("w0", "w1", "w2"):
        mon.heartbeat(w, tick_latency=1.0)
    mon.heartbeat("w3", tick_latency=10.0)
    assert mon.stragglers() == ["w3"]


def test_quarantine_not_reflagged():
    """A quarantined worker leaves ``stragglers()`` and ``active()`` — it
    must not be re-flagged on the next poll."""
    clk = FakeClock()
    mon = HeartbeatMonitor(["w0", "w1", "w2"], straggler_factor=3.0,
                           ewma=1.0, clock=clk)
    for w in ("w0", "w1"):
        mon.heartbeat(w, tick_latency=1.0)
    mon.heartbeat("w2", tick_latency=9.0)
    assert mon.stragglers() == ["w2"]
    mon.quarantine("w2")
    assert mon.stragglers() == []                     # no double-fire
    assert sorted(mon.active()) == ["w0", "w1"]
    mon.heartbeat("w2", tick_latency=9.0)             # still beating, still out
    assert mon.stragglers() == []


def test_zero_latency_fleet_has_no_stragglers():
    """All-zero EWMAs are valid samples and nobody stands out."""
    clk = FakeClock()
    mon = HeartbeatMonitor(["w0", "w1", "w2"], ewma=1.0, clock=clk)
    for w in ("w0", "w1", "w2"):
        mon.heartbeat(w, tick_latency=0.0)
    assert mon.stragglers() == []


# ---------------------------------------------------------------------------
# ElasticMesh
# ---------------------------------------------------------------------------

def test_grid_shrinks_data_axis_on_worker_loss():
    em = ElasticMesh(model_parallel=2)
    assert em.grid_for(8) == (4, 2)
    assert em.grid_for(6) == (3, 2)    # lost 2 workers: data axis 4 -> 3
    assert em.grid_for(2) == (1, 2)
    with pytest.raises(RuntimeError):
        em.grid_for(1)                 # cannot host the model axis


def test_make_mesh_uses_largest_feasible_grid():
    import jax

    em = ElasticMesh(model_parallel=1)
    mesh = em.make_mesh(jax.devices())
    assert mesh.shape["data"] == len(jax.devices())
    assert mesh.shape["model"] == 1


def test_rebalance_streams_round_robin():
    em = ElasticMesh(model_parallel=1)
    out = em.rebalance_streams(list(range(7)), 3)
    assert out == [[0, 3, 6], [1, 4], [2, 5]]
    assert sorted(s for grp in out for s in grp) == list(range(7))
    # scale-down: the same streams re-pack densely onto fewer shards
    out2 = em.rebalance_streams([s for grp in out for s in grp], 2)
    assert sum(len(g) for g in out2) == 7
    assert abs(len(out2[0]) - len(out2[1])) <= 1
