"""Multi-camera identity detection (§5.4): probability propagation + search."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DetectorParams, identity_detection
from repro.core.detect import propagate


def test_propagation_probability_mass(duke_sim):
    model = duke_sim["model"]
    W = 64
    I = jnp.ones((1, model.n_cams, W))
    # paper-literal prior (rho=0): window 0 is exactly the mixed prior
    p0 = DetectorParams(window=20, surface_rho=0.0)
    P = np.asarray(propagate(model, I, W, p0))
    inbound = np.asarray(model.counts).sum(0)
    occ = inbound / max(inbound.sum(), 1.0)
    prior = 0.5 * occ + 0.5 * np.asarray(model.entry)
    assert (P >= -1e-6).all()
    np.testing.assert_allclose(P[0, :, 0], prior, atol=1e-6)
    # surfacing prior: still non-negative, mass bounded
    P2 = np.asarray(propagate(model, I, W, DetectorParams(window=20)))
    assert (P2 >= -1e-6).all()
    assert P2[0].sum() <= W + 1.0


def test_scanned_cells_stop_contributing(duke_sim):
    model = duke_sim["model"]
    p = DetectorParams(window=20)
    W = duke_sim["vis"].horizon // p.window
    I_all = jnp.ones((1, model.n_cams, W))
    I_cut = I_all.at[:, :, :3].set(0.0)
    P_all = np.asarray(propagate(model, I_all, W, p))
    P_cut = np.asarray(propagate(model, I_cut, W, p))
    # cutting early windows removes downstream probability mass
    assert P_cut[0, :, 3:].sum() <= P_all[0, :, 3:].sum() + 1e-6


def test_detection_cheaper_than_baseline(duke_sim):
    from repro.core.detect import make_detection_queries

    vis, feats, model = duke_sim["vis"], duke_sim["feats"], duke_sim["model"]
    t0 = 1200
    q = make_detection_queries(vis, 12, search_start=t0, seed=2)
    p = DetectorParams(theta=0.95, window=20)
    rex = identity_detection(model, vis, feats, q, p, t_refs=t0)
    base = identity_detection(model, vis, feats, q, p, baseline=True, t_refs=t0)
    assert rex["cost"] < base["cost"]
    assert rex["recall"] > 0.5
    assert rex["recall"] >= base["recall"] - 0.2


def test_lower_theta_scans_more(duke_sim):
    from repro.core.detect import make_detection_queries

    vis, feats, model = duke_sim["vis"], duke_sim["feats"], duke_sim["model"]
    t0 = 1200
    q = make_detection_queries(vis, 6, search_start=t0, seed=3)
    hi = identity_detection(model, vis, feats, q, DetectorParams(theta=0.95), t_refs=t0)
    lo = identity_detection(model, vis, feats, q, DetectorParams(theta=0.75), t_refs=t0)
    assert lo["recall"] >= hi["recall"] - 1e-6
    assert lo["rounds"] <= hi["rounds"]
