"""Substrate services: optimizer, compression, checkpointing, data, runtime."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import (CheckpointManager, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.manager import gc_checkpoints
from repro.data import SyntheticLMStream
from repro.optim import (OptConfig, adamw_update, dequantize_int8,
                         init_compression_state, init_opt_state, lr_at,
                         quantize_int8)
from repro.optim.compress import compress_with_feedback
from repro.runtime import ElasticMesh, FrameStore, HeartbeatMonitor


# -- optimizer ---------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    w = {"w": jnp.array([3.0, -2.0, 1.0])}
    opt = init_opt_state(w)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)

    @jax.jit
    def step(w, opt):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
        return adamw_update(w, g, opt, cfg)

    for _ in range(100):
        w, opt, m = step(w, opt)
    assert float(jnp.abs(w["w"]).max()) < 0.2
    assert int(opt["step"]) == 100


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(jnp.asarray(0), cfg)) == 0.0
    assert float(lr_at(jnp.asarray(10), cfg)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(jnp.asarray(100), cfg)) == pytest.approx(0.1, rel=1e-2)


def test_grad_clipping_bounds_update():
    w = {"w": jnp.ones(4)}
    opt = init_opt_state(w)
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw_update(w, g, opt, cfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


# -- int8 error-feedback compression ----------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_quantize_roundtrip_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the accumulated dequantized sum tracks the true
    gradient sum (compression error does not accumulate)."""
    key = jax.random.PRNGKey(0)
    err = jnp.zeros((256,))
    true_sum = jnp.zeros((256,))
    deq_sum = jnp.zeros((256,))
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (256,))
        q, s, err = compress_with_feedback(g, err)
        deq_sum = deq_sum + dequantize_int8(q, s)
        true_sum = true_sum + g
    # residual bounded by one quantization step, NOT growing with steps
    assert float(jnp.abs(deq_sum + err - true_sum).max()) < 1e-3


# -- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3), "b": {"c": np.float32(2.5)}}
    save_checkpoint(str(tmp_path), 7, tree)
    step, back = restore_checkpoint(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(back["a"], tree["a"])
    assert back["b"]["c"] == tree["b"]["c"]


def test_checkpoint_crash_safety(tmp_path):
    """A half-written tmp dir is invisible to restore and removed by GC."""
    save_checkpoint(str(tmp_path), 1, {"x": np.ones(3)})
    litter = tmp_path / "step_00000002.tmp-dead"
    litter.mkdir()
    (litter / "leaf_00000.npy").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 1
    gc_checkpoints(str(tmp_path), keep=3)
    assert not litter.exists()


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, {"x": np.full(4, s)})
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    step, tree = mgr.restore_latest()
    assert step == 4 and tree["x"][0] == 4


def test_restart_resumes_from_latest(tmp_path):
    """Simulated failure: train 3 steps, 'crash', resume at step 3."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": np.zeros(2), "stream": {"cursor": 0, "seed": 0}}
    for s in range(1, 4):
        state = {"w": state["w"] + 1, "stream": {"cursor": s, "seed": 0}}
        mgr.save(s, state, blocking=True)
    del mgr, state  # crash
    step, state = CheckpointManager(str(tmp_path)).restore_latest()
    assert step == 3 and state["w"][0] == 3 and state["stream"]["cursor"] == 3


# -- data pipeline -----------------------------------------------------------

def test_stream_deterministic_and_resumable():
    a = SyntheticLMStream(vocab_size=64, seq_len=32, global_batch=4, seed=1)
    b1 = [a.next_batch()["tokens"] for _ in range(3)]
    b = SyntheticLMStream(vocab_size=64, seq_len=32, global_batch=4, seed=1)
    b.load_state_dict({"cursor": 2, "seed": 1})
    np.testing.assert_array_equal(b.next_batch()["tokens"], b1[2])


def test_stream_host_sharding_disjoint():
    full = SyntheticLMStream(vocab_size=64, seq_len=16, global_batch=8, seed=2)
    h0 = SyntheticLMStream(vocab_size=64, seq_len=16, global_batch=8, seed=2,
                           process_index=0, process_count=2)
    h1 = SyntheticLMStream(vocab_size=64, seq_len=16, global_batch=8, seed=2,
                           process_index=1, process_count=2)
    assert h0.local_batch == h1.local_batch == 4
    t0, t1 = h0.next_batch()["tokens"], h1.next_batch()["tokens"]
    assert not np.array_equal(t0, t1)


def test_stream_is_learnable_structure():
    """Bigram process: successor entropy must be far below uniform."""
    s = SyntheticLMStream(vocab_size=64, seq_len=256, global_batch=8, seed=0,
                          branching=4)
    toks = s.next_batch()["tokens"]
    pairs = set(zip(toks[:, :-1].ravel().tolist(), toks[:, 1:].ravel().tolist()))
    # at most branching successors per token
    from collections import defaultdict
    succ = defaultdict(set)
    for a, b in pairs:
        succ[a].add(b)
    assert max(len(v) for v in succ.values()) <= 4


# -- runtime / fault tolerance ------------------------------------------------

def test_frame_store_retention_and_replay_range():
    fs = FrameStore(n_cams=2, retention=10)
    for t in range(25):
        fs.append(0, t, f"f{t}")
    assert fs.get(0, 20) == "f20"
    with pytest.raises(KeyError):
        fs.get(0, 5)  # evicted
    rng = fs.range(0, 0, 24)
    assert rng[0][0] >= 14 and rng[-1][0] == 24


def test_frame_store_embedding_cache_evicts_with_frames():
    fs = FrameStore(n_cams=1, retention=10)
    for t in range(5):
        fs.append(0, t, f"f{t}")
    assert fs.put_emb(0, 3, "e3")            # retained: cached (True)
    assert fs.get_emb(0, 3) == "e3"
    assert fs.get_emb(0, 4) is None          # frame retained, never embedded
    assert fs.cached_embeddings() == 1
    for t in range(5, 30):
        fs.append(0, t, f"f{t}")
    assert fs.get_emb(0, 3) is None          # evicted together with its frame
    assert fs.cached_embeddings() == 0
    assert not fs.put_emb(0, 2, "stale")     # past retention: refused
    assert fs.get_emb(0, 2) is None
    assert fs.put_emb(0, 25, "e25")          # retained: accepted
    assert fs.get_emb(0, 25) == "e25"


def test_frame_store_eviction_is_amortized_o1():
    """Eviction pops only the keys that crossed the horizon — the total
    number of popped keys over N appends is N, not N * retention."""
    fs = FrameStore(n_cams=1, retention=50)
    for t in range(500):
        fs.append(0, t, t)
    assert fs.memory_frames() == 51          # [latest - retention, latest]
    assert len(fs._keys[0]) == 51            # deque tracks exactly the window


def test_heartbeat_dead_and_straggler_detection():
    t = [0.0]
    mon = HeartbeatMonitor(["a", "b", "c"], timeout=5.0, clock=lambda: t[0])
    for _ in range(5):
        mon.heartbeat("a", 1.0)
        mon.heartbeat("b", 1.1)
        mon.heartbeat("c", 9.0)   # straggler
    assert mon.stragglers() == ["c"]
    mon.quarantine("c")
    t[0] = 10.0
    mon.heartbeat("a", 1.0)
    assert "b" in mon.dead()
    assert mon.active() == ["a"]


def test_elastic_mesh_shrinks_data_axis():
    em = ElasticMesh(model_parallel=2)
    assert em.grid_for(8) == (4, 2)
    assert em.grid_for(7) == (3, 2)  # drops one device
    with pytest.raises(RuntimeError):
        em.grid_for(1)
    groups = em.rebalance_streams(list(range(10)), 3)
    assert sum(len(g) for g in groups) == 10
    assert max(len(g) for g in groups) - min(len(g) for g in groups) <= 1


def test_serving_engine_end_to_end(duke_sim):
    """Engine tracks a query through the duke sim using the feature oracle."""
    from repro.runtime import EngineConfig, ServingEngine

    vis, gal, feats, model = (duke_sim["vis"], duke_sim["gal"],
                              duke_sim["feats"], duke_sim["model"])
    q = int(duke_sim["q_vids"][0])
    eng = ServingEngine(model, embed_fn=lambda x: x, cfg=EngineConfig())
    t0, t1 = int(vis.t_out[q]), min(int(vis.t_out[q]) + 300, vis.horizon)
    eng.t = t0
    eng.submit_query(0, feats[q], int(vis.cam[q]), t0)
    for t in range(t0, t1):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t]
            vids = vids[vids >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        eng.tick()
    qs = eng.queries[0]
    # the engine must have processed far fewer frames than cams x steps
    assert eng.frames_processed < (t1 - t0) * vis.n_cams * 0.7


def test_serving_tile_all_admitted_matches_camera_path():
    """Single-engine half of the tile differential (tier-1, no fake-device
    mesh): ``tile_grid=T`` over a tile-less model is trace-identical to
    camera-granular serving, and the tile counters tile T*T exactly."""
    from conftest import drive_serving_trace, make_serving_world, trace_key
    from repro.core.policy import SearchPolicy

    T = 4
    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    _, ref_trace, ref_sum = drive_serving_trace(world, policy)
    eng, tr, sm = drive_serving_trace(world, policy, tile_grid=T)
    assert trace_key(tr) == trace_key(ref_trace)
    assert sm["per_query"] == ref_sum["per_query"]
    assert sm["admitted_steps"] == ref_sum["admitted_steps"]
    assert eng.admitted_tiles == T * T * eng.admitted_steps
    assert eng.unique_tiles == T * T * eng.unique_frames


def test_serving_tile_learned_masks_prune_without_match_loss():
    """Learned entry-region masks (profiled from the same world's ground
    truth) must strictly shrink the admitted tile load while every query's
    match outcome stays identical — the recall-safe construction
    (mass-coverage threshold + dilation halo + phase/self-camera
    relaxations) in miniature."""
    from conftest import drive_serving_trace, make_serving_world
    from repro import api as rexcam
    from repro.core.policy import SearchPolicy

    T = 4
    world = make_serving_world(seed=0, n_queries=4)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    tile_model = rexcam.profile(world["vis"], time_limit=252, tile_grid=T)
    assert tile_model.tile_grid == T
    assert tile_model.tile_admit.shape == (8, 8, T * T)
    base, _, base_sum = drive_serving_trace(world, policy, tile_grid=T)
    eng, _, sm = drive_serving_trace(world, policy, tile_grid=T,
                                     model=tile_model)
    assert sm["per_query"] == base_sum["per_query"], \
        "learned tile masks changed a match outcome"
    assert eng.admitted_tiles < base.admitted_tiles, \
        f"learned masks pruned nothing: {eng.admitted_tiles} vs " \
        f"{base.admitted_tiles} all-admitted tiles"


def test_serving_tile_ingest_requires_labels():
    """Tile mode makes per-detection tile labels MANDATORY at ingest: a
    missing camera or a length mismatch raises instead of silently serving
    unrankable gallery rows."""
    from conftest import make_serving_world
    from repro import api as rexcam
    from repro.core.policy import SearchPolicy

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       policy=SearchPolicy(), tile_grid=4)
    crops = np.zeros((3, world["feats"].shape[1]), np.float32)
    with pytest.raises(ValueError, match="tile labels"):
        eng.ingest({0: crops})
    with pytest.raises(ValueError, match="tile labels"):
        eng.ingest({0: crops}, {1: np.zeros(3, np.int32)})
    with pytest.raises(ValueError, match="3 detections"):
        eng.ingest({0: crops}, {0: np.zeros(2, np.int32)})
    eng.ingest({0: crops}, {0: np.zeros(3, np.int32)})   # labeled: accepted


def test_serving_topk_rerank_k1_bit_identical():
    """§5.2 top-k confidence re-ranking at k=1 degrades to plain argmax
    BIT-identically (one passing band is its own vote winner), and at k=3
    the voting path still runs the full differential world without
    diverging the admission/phase plane."""
    from conftest import drive_serving_trace, make_serving_world, trace_key
    from repro.core.policy import SearchPolicy

    world = make_serving_world(seed=0, n_queries=4)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    _, ref_trace, ref_sum = drive_serving_trace(world, policy, topk=1)
    _, rr_trace, rr_sum = drive_serving_trace(world, policy, topk=1,
                                              topk_rerank=True)
    assert trace_key(rr_trace) == trace_key(ref_trace), \
        "topk_rerank at k=1 is not bit-identical to the argmax path"
    assert rr_sum["per_query"] == ref_sum["per_query"]
    # k=3 rerank: a live sanity run; re-anchoring may legitimately change
    # trajectories, but the admission plane itself is rerank-independent —
    # round 1's masks (before any match can diverge) must agree
    _, k3_trace, k3_sum = drive_serving_trace(world, policy, topk=3,
                                              topk_rerank=True)
    assert k3_trace and len(k3_sum["per_query"]) == 4
    first = {r["qid"]: tuple(map(bool, r["mask"])) for r in k3_trace[:4]}
    ref_first = {r["qid"]: tuple(map(bool, r["mask"])) for r in ref_trace[:4]}
    assert first == ref_first
