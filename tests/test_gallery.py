"""The gallery plane (PR-4 tentpole): GalleryStore semantics, FrameStore
delegation, engine/api wiring and the top-k trace bands — everything that
runs on one device.  The fleet-level differential contracts (sharded vs
local gallery trace identity, counting embed_fn, shard re-homing, O(1)
load accounting under rebalance) live in tests/test_sharded_engine.py via
the 8-fake-device harness."""
import numpy as np
import pytest

from repro.runtime import FrameStore
from repro.runtime.gallery import (LocalGalleryStore, ShardedGalleryStore,
                                   assemble_round_gallery, pow2)


# -- GalleryStore contract ---------------------------------------------------

def test_local_gallery_store_counters_and_horizon():
    g = LocalGalleryStore(n_cams=2, retention=10)
    e5 = np.ones((3, 4), np.float32)
    assert g.put(0, 5, e5)
    assert g.get(0, 5) is e5 and g.hits == 1
    assert g.get(0, 6) is None and g.misses == 1
    assert g.get(1, 5) is None               # cameras are independent
    # a put far behind the horizon is refused, not silently dropped
    assert g.put(0, 100, np.zeros((1, 4), np.float32))
    assert not g.put(0, 5, e5)
    assert g.rejected == 1
    # ...and the horizon-advance evicted the old entry
    assert g.get(0, 5) is None
    assert g.evictions == 1
    assert g.cached_embeddings() == 1
    c = g.counters()
    assert c["cached"] == 1 and c["bytes"] == 4 * 4


def test_gallery_store_out_of_order_deferred_eviction():
    """The FrameStore invariants, on the store itself: an out-of-order put
    below a later horizon is rejected; one ABOVE the horizon is accepted
    but its eviction may defer until the deque head catches up — during
    which ``get`` re-checks the horizon and never serves it stale."""
    g = LocalGalleryStore(n_cams=1, retention=60)
    g.put(0, 100, "e100")
    assert g.put(0, 50, "e50")               # out of order, still retained
    assert g.get(0, 50) == "e50"
    g.put(0, 120, "e120")                    # horizon -> 60: 50 is now stale
    # deferred eviction: the deque head (100) hasn't crossed the horizon,
    # so the entry is still resident... but get re-checks and refuses it
    assert g.cached_embeddings() == 3
    assert g.get(0, 50) is None
    # deque catch-up: horizon passes 100, popping it AND the deferred 50
    g.put(0, 165, "e165")
    assert g.cached_embeddings() == 2        # {120, 165}
    assert g.get(0, 120) == "e120" and g.get(0, 165) == "e165"


def test_sharded_gallery_store_device_blocks_roundtrip():
    """Single-worker sharded store: blocks live on the owner device, rows
    pow2-padded, and round-trip bit-exactly (what keeps the sharded-gallery
    fleet trace-identical)."""
    import jax

    dev = jax.devices()[0]
    g = ShardedGalleryStore(n_cams=3, retention=50, workers=["w0"],
                            device_of={"w0": dev})
    assert all(g.owner_of(c) == "w0" for c in range(3))
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(5, 8)).astype(np.float32)
    assert g.put(1, 7, emb)
    arr, n = g._blocks[(1, 7)]
    assert n == 5 and arr.shape == (pow2(5), 8)      # pow2 row padding
    assert {d for d in arr.devices()} == {dev}
    np.testing.assert_array_equal(g.get(1, 7), emb)  # bit-exact roundtrip
    rep = g.per_worker_report()
    assert rep["w0"]["cameras"] == 3 and rep["w0"]["blocks"] == 1
    assert rep["w0"]["rows"] == 5 and rep["w0"]["bytes"] == arr.nbytes
    assert g.memory_bytes() == arr.nbytes
    with pytest.raises(RuntimeError):
        g.rehome("w0", [])                   # no survivors: fail loudly


def test_sharded_gallery_rehome_moves_only_the_lost_shard():
    import jax

    dev = jax.devices()[0]
    g = ShardedGalleryStore(n_cams=8, retention=50, workers=["w0", "w1"],
                            device_of={"w0": dev, "w1": dev})
    owners = dict(g._owner)
    assert set(owners.values()) == {"w0", "w1"}      # hash spreads cameras
    for cam in range(8):
        g.put(cam, 3, np.full((2, 4), cam, np.float32))
    lost_cams = [c for c, w in owners.items() if w == "w0"]
    moved = g.rehome("w0", ["w1"])
    assert moved == len(lost_cams) == g.rehomed_blocks
    assert set(g._owner.values()) == {"w1"}
    for cam, w in owners.items():
        if w != "w0":                        # survivors keep their cameras
            assert g._owner[cam] == w
    for cam in range(8):                     # values survive the migration
        np.testing.assert_array_equal(g.get(cam, 3),
                                      np.full((2, 4), cam, np.float32))


def test_assemble_round_gallery_camera_major_and_pow2():
    keys = [(0, 5), (1, 5), (2, 4)]
    key_emb = {(0, 5): np.ones((2, 4), np.float32),
               (1, 5): np.full((1, 4), 2, np.float32),
               (2, 4): np.full((2, 4), 3, np.float32)}
    gal, gal_cam, gal_frame = assemble_round_gallery(keys, key_emb)
    assert gal.shape == (8, 4)               # 5 rows padded to pow2
    np.testing.assert_array_equal(gal_cam[:5], [0, 0, 1, 2, 2])
    np.testing.assert_array_equal(gal_frame[:5], [5, 5, 5, 4, 4])
    assert (gal_cam[5:] == -1).all() and (gal_frame[5:] == -1).all()
    assert (gal[5:] == 0).all()


# -- FrameStore delegation ---------------------------------------------------

def test_frame_store_put_emb_returns_cached_or_not():
    """Satellite: ``put_emb`` reports whether the write stuck — a frame
    never appended (or already evicted) is refused, not silently dropped."""
    fs = FrameStore(n_cams=1, retention=10)
    assert not fs.put_emb(0, 3, "orphan")    # frame never appended
    assert fs.get_emb(0, 3) is None
    fs.append(0, 3, "f3")
    assert fs.put_emb(0, 3, "e3")            # retained: accepted
    assert fs.get_emb(0, 3) == "e3"
    for t in range(4, 30):
        fs.append(0, t, f"f{t}")
    assert not fs.put_emb(0, 3, "stale")     # evicted since: refused
    assert fs.get_emb(0, 3) is None


def test_frame_store_out_of_order_append_deferred_eviction():
    """Satellite: the module-docstring invariants, pinned.  An out-of-order
    append stays correct — ``get`` re-checks the horizon — and its eviction
    defers until the deque head reaches it."""
    fs = FrameStore(n_cams=1, retention=60)
    fs.append(0, 100, "f100")
    fs.append(0, 50, "f50")                  # out of order, still retained
    assert fs.get(0, 50) == "f50"
    fs.append(0, 120, "f120")                # horizon -> 60
    # 50 is behind the horizon but the deque head (100) isn't: eviction is
    # deferred, the frame is still resident...
    assert fs.memory_frames() == 3
    with pytest.raises(KeyError):            # ...but get re-checks
        fs.get(0, 50)
    # range reads clamp to the horizon too: the deferred frame is invisible
    assert fs.range(0, 0, 200) == [(100, "f100"), (120, "f120")]
    # deque catch-up: horizon passes 100 -> pops 100, then the deferred 50
    fs.append(0, 165, "f165")
    assert fs.memory_frames() == 2           # {120, 165}
    assert fs.get(0, 120) == "f120" and fs.get(0, 165) == "f165"


def test_frame_store_out_of_order_embeddings_follow_frames():
    """Same invariants one layer down: embeddings cached for a deferred
    frame are refused on read and dropped on the deque catch-up."""
    fs = FrameStore(n_cams=1, retention=60)
    fs.append(0, 100, "f100")
    fs.append(0, 50, "f50")
    assert fs.put_emb(0, 50, "e50")
    fs.append(0, 120, "f120")                # 50 now behind the horizon
    assert fs.get_emb(0, 50) is None         # horizon re-check on read
    assert fs.cached_embeddings() == 1       # eviction deferred...
    fs.append(0, 165, "f165")                # ...until deque catch-up
    assert fs.cached_embeddings() == 0
    assert fs.gallery.evictions == 1


def test_frame_store_delegates_to_injected_store():
    inj = LocalGalleryStore(n_cams=2, retention=10)
    fs = FrameStore(n_cams=2, retention=10, gallery=inj)
    assert fs.gallery is inj
    fs.append(1, 4, "f")
    assert fs.put_emb(1, 4, "e")
    assert inj.get(1, 4) == "e"              # landed in the injected store
    assert fs.cached_embeddings() == inj.cached_embeddings() == 1
    assert inj.puts == 1 and inj.hits == 1


# -- engine / api wiring -----------------------------------------------------

def test_serve_gallery_knob():
    from repro import api as rexcam
    from repro.runtime.engine import EngineConfig, ServingEngine
    from conftest import make_serving_world

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    single = rexcam.serve(world["model"], embed_fn=lambda x: x)
    assert single.gallery.kind == "local"
    assert single.gallery_report()["kind"] == "local"
    # sharded is a fleet-only mode: the single engine fails loudly
    with pytest.raises(ValueError):
        rexcam.serve(world["model"], embed_fn=lambda x: x, gallery="sharded")
    with pytest.raises(ValueError):
        ServingEngine(world["model"], lambda x: x,
                      EngineConfig(gallery="bogus"))
    # the fleet defaults to the fleet-shared sharded store...
    fleet = rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1)
    assert fleet.gallery.kind == "sharded"
    assert fleet.store.gallery is fleet.gallery
    assert "per_worker" in fleet.gallery_report()
    # ...and can be forced back to the replicated baseline
    local = rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1,
                         gallery="local")
    assert local.gallery.kind == "local"
    with pytest.raises(ValueError):
        rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1,
                     gallery="bogus")
    # topk < 1 fails at construction, not deep inside the jitted round
    with pytest.raises(ValueError):
        rexcam.serve(world["model"], embed_fn=lambda x: x, topk=0)


def test_fleet_sharded_gallery_lives_on_the_data_axis():
    """shards=1 fleet end-to-end on any device count: the engine's cache
    round-trips through the device-resident sharded store and the owner
    attribution tiles the global dedup exactly."""
    from repro.core.policy import SearchPolicy
    from conftest import assert_fleet_trace_identical, make_serving_world

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    eng, _ = assert_fleet_trace_identical(world, policy, shards=1)
    assert eng.gallery.kind == "sharded"
    rep = eng.shard_report()
    assert sum(r["owned_frames"] for r in rep) == eng.unique_frames
    g = eng.gallery_report()
    assert g["per_worker"]["w0"]["cameras"] == eng.C


def test_fleet_load_counters_track_completions():
    """Satellite (tier-1 slice): the O(1) live-load counters equal the
    brute placement scan across submits and query completions.  The
    rebalance leg runs in the 8-device harness."""
    from repro import api as rexcam
    from repro.core.policy import SearchPolicy
    from conftest import make_serving_world

    def brute(eng, worker):
        return sum(1 for qid, w in eng._placement.items()
                   if w == worker and qid in eng.queries
                   and not eng.queries[qid].done)

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=3)
    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=40)
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, policy=policy,
                       geo_adj=world["net"].geo_adjacent, shards=1)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
        assert eng._load("w0") == brute(eng, "w0")
    for t in range(t0, vis.horizon + 200):
        if t < vis.horizon:
            frames = {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
            eng.ingest(frames)
        eng.tick()
        assert eng._load("w0") == brute(eng, "w0")
        if all(q.done for q in eng.queries.values()):
            break
    assert all(q.done for q in eng.queries.values())
    assert eng._load("w0") == 0


def test_replay_miss_conventions_per_key_and_per_step():
    """Satellite: an evicted (cam, frame) key wanted by k queries is ONE
    cold-storage miss in the per-key convention (``replay_misses``) but k
    failed rescue steps in admitted_steps' per-(query, camera) convention
    (``replay_miss_steps``) — both surface in ``gallery_report()``.  Pinned
    with 3 same-anchor queries replaying into a fully-evicted window: every
    round misses C keys but 3C steps."""
    from repro import api as rexcam
    from repro.core.policy import SearchPolicy
    from conftest import make_serving_world

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    C = world["model"].n_cams
    p = SearchPolicy(scheme="all", exit_t=60, replay_speed=1)
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, policy=p,
                       retention=4)
    eng.t = 50
    # one fresh frame per camera pushes every horizon past the replay window
    eng.ingest({c: np.ones((2, 16), np.float32) for c in range(C)})
    eng.t = 51
    for qid in range(3):
        eng.submit_query(qid, np.ones(16, np.float32), 0, 0)
    R = 10
    for _ in range(R):
        stats = eng.tick()
        # per tick: one round, all 3 cursors on one frame, C admitted keys
        assert stats["replay_misses"] == C
        assert stats["replay_miss_steps"] == 3 * C
    assert eng.replay_misses == C * R
    assert eng.replay_miss_steps == 3 * C * R
    rep = eng.gallery_report()
    assert rep["replay_misses"] == C * R
    assert rep["replay_miss_steps"] == 3 * C * R


# -- top-k candidate bands ---------------------------------------------------

def test_topk_bands_surface_without_changing_argmax():
    """Satellite: topk=3 surfaces (value, cam, frame) candidate bands in
    every trace record while the argmax match path (and therefore the whole
    trace minus the bands) is bit-identical to topk=1."""
    from repro.core.policy import SearchPolicy
    from repro.kernels.reid_topk import NEG_INF
    from conftest import drive_serving_trace, make_serving_world, trace_key

    world = make_serving_world(n_entities=80, horizon=300, seed=4,
                               n_queries=3)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    _, tr1, sum1 = drive_serving_trace(world, policy, topk=1)
    _, tr3, sum3 = drive_serving_trace(world, policy, topk=3)

    strip = lambda key: [r[:-1] for r in key]    # drop the topk element
    assert strip(trace_key(tr3)) == strip(trace_key(tr1))
    assert sum3["per_query"] == sum1["per_query"]

    assert all(len(r["topk"]) == 3 for r in tr3)
    assert all(len(r["topk"]) == 1 for r in tr1)
    saw_multi = False
    for r in tr3:
        vals = [b[0] for b in r["topk"]]
        assert vals == sorted(vals, reverse=True)    # bands are descending
        assert r["topk"][0][0] == r["match_val"]     # band 0 IS the argmax
        if r["matched"]:
            assert r["topk"][0][1] == r["match_cam"]
            assert r["topk"][0][2] == r["f_curr"]    # candidates at cursor
        for v, cam, frame in r["topk"]:
            if v <= NEG_INF / 2:                     # empty band: sentinel
                assert cam == -1 and frame == -1
            else:
                assert 0 <= cam < world["net"].n_cams
                saw_multi = saw_multi or r["topk"][1][0] > NEG_INF / 2
    assert saw_multi, "no round ever had a second candidate — world too easy"
