"""End-to-end behaviour tests for the paper's system (ReXCam §5, §8)."""
import numpy as np
import pytest

from repro.core import TrackerParams, track_queries


def _run(duke_sim, p):
    return track_queries(duke_sim["model"], duke_sim["vis"], duke_sim["gal"],
                         duke_sim["feats"], duke_sim["q_vids"],
                         duke_sim["gt_vids"], p,
                         geo_adj=duke_sim["net"].geo_adjacent)


def test_rexcam_beats_baseline_cost(duke_sim):
    base = _run(duke_sim, TrackerParams(scheme="all"))
    rex = _run(duke_sim, TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02))
    savings = base.total_cost / max(rex.total_cost, 1)
    assert savings > 3.0, f"expected >3x savings, got {savings:.2f}x"


def test_rexcam_improves_precision(duke_sim):
    base = _run(duke_sim, TrackerParams(scheme="all"))
    rex = _run(duke_sim, TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02))
    assert rex.precision > base.precision + 0.05, (rex.precision, base.precision)


def test_rexcam_recall_close_to_baseline(duke_sim):
    base = _run(duke_sim, TrackerParams(scheme="all"))
    rex = _run(duke_sim, TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02))
    assert rex.recall > base.recall - 0.15, (rex.recall, base.recall)


def test_replay_rescues_reduce_recall_loss(duke_sim):
    """Disabling replay must lose recall vs replay-enabled ReXCam (§5.3)."""
    with_replay = _run(duke_sim, TrackerParams(scheme="rexcam"))
    without = _run(duke_sim, TrackerParams(scheme="rexcam", use_replay=False))
    assert with_replay.recall >= without.recall
    assert with_replay.rescued.sum() > 0


def test_replay_modes_tradeoffs(duke_sim):
    """Fig. 15: 2x skip cuts cost+delay; 2x ff cuts delay at same cost."""
    normal = _run(duke_sim, TrackerParams(scheme="rexcam"))
    skip = _run(duke_sim, TrackerParams(scheme="rexcam", replay_skip=2))
    ff = _run(duke_sim, TrackerParams(scheme="rexcam", replay_speed=2.0))
    assert skip.mean_delay <= normal.mean_delay + 1e-6
    assert ff.mean_delay <= normal.mean_delay + 1e-6
    assert skip.total_cost <= normal.total_cost + 1e-6


def test_more_aggressive_thresholds_cost_less(duke_sim):
    mild = _run(duke_sim, TrackerParams(scheme="rexcam", s_thresh=.01, t_thresh=.01))
    aggr = _run(duke_sim, TrackerParams(scheme="rexcam", s_thresh=.10, t_thresh=.10))
    assert aggr.total_cost < mild.total_cost


def test_spatial_only_saves_less_than_spatiotemporal(duke_sim):
    sp = _run(duke_sim, TrackerParams(scheme="spatial_only", s_thresh=.05))
    st = _run(duke_sim, TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02))
    assert st.total_cost < sp.total_cost


def test_exhaustive_final_recovers_more_but_costs_more(duke_sim):
    default = _run(duke_sim, TrackerParams(scheme="rexcam"))
    exha = _run(duke_sim, TrackerParams(scheme="rexcam", exhaustive_final=True))
    assert exha.total_cost >= default.total_cost
    assert exha.recall >= default.recall - 0.02


def test_drift_detection_signal(duke_sim):
    """§6: replay rescues accumulate per camera pair (re-profiling trigger)."""
    rex = _run(duke_sim, TrackerParams(scheme="rexcam"))
    assert rex.rescue_pairs.shape == (8, 8)
    assert rex.rescue_pairs.sum() == rex.rescued.sum()


def test_drift_detection_and_reprofiling():
    """Paper §6 end-to-end: a mid-run correlation change spikes replay
    rescues on the changed pair; re-profiling restores recall."""
    import dataclasses as _dc

    import numpy as np

    from repro.core import build_gallery, build_model, duke_like_network, simulate_network
    from repro.core.features import FeatureParams, make_features
    from repro.core.profiler import drift_score
    from repro.core.tracker import make_queries

    net = duke_like_network()
    T = net.trans.copy()
    moved = T[0, 1] * 0.9       # reroute into the uncorrelated c1->c5 pair
    T[0, 1] -= moved
    T[0, 4] += moved
    changed = _dc.replace(net, trans=T)

    hist = simulate_network(net, 800, 2000, seed=31)
    stale = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, net.n_cams)
    vis = simulate_network(changed, 800, 2000, seed=32)
    gal, _ = build_gallery(vis, 24)
    feats, _ = make_features(vis, 800, FeatureParams(seed=32))
    q, gt = make_queries(vis, 25, seed=33)
    p = TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02)

    r_stale = track_queries(stale, vis, gal, feats, q, gt, p,
                            geo_adj=net.geo_adjacent)
    score = drift_score(stale, r_stale.rescue_pairs)
    hot = np.unravel_index(np.argmax(score), score.shape)
    assert hot[0] == 0, f"drift localized to wrong source camera: {hot}"

    fresh_model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                              time_limit=1400)
    r_fresh = track_queries(fresh_model, vis, gal, feats, q, gt, p,
                            geo_adj=net.geo_adjacent)
    assert r_fresh.recall >= r_stale.recall - 0.02
    assert r_fresh.rescued.sum() <= r_stale.rescued.sum()


# ---------------------------------------------------------------------------
# the BENCH record golden schema (the persistent perf trajectory's contract)
# ---------------------------------------------------------------------------

def _bench_scenarios():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import scenarios
    return scenarios


def test_bench_record_rejects_missing_required_keys():
    scenarios = _bench_scenarios()
    with pytest.raises(ValueError, match="missing required keys"):
        scenarios.bench_record("_schema_probe", scenario="x",
                               admitted_steps=1)
    assert scenarios.pop_bench_records("_schema_probe") == []
    # a full measured row and a derived summary row both pass
    scenarios.bench_record("_schema_probe", scenario="x", admitted_steps=1,
                           unique_frames=1, wall_s=0.1, p50_tick_ms=1.0,
                           p99_tick_ms=2.0)
    scenarios.bench_record("_schema_probe", derived=True, savings_x=21.0)
    assert len(scenarios.pop_bench_records("_schema_probe")) == 2


def test_every_bench_record_call_site_satisfies_the_schema():
    """Static golden-schema audit: every ``bench_record(...)`` call in
    benchmarks/ passes all ``REQUIRED_BENCH_KEYS`` as explicit keywords (or
    opts out with ``derived=True``) — so a schema violation is caught at
    review time, not only when the offending sweep happens to run."""
    import ast
    import os

    scenarios = _bench_scenarios()
    bench_dir = os.path.join(os.path.dirname(__file__), "..", "benchmarks")
    required = set(scenarios.REQUIRED_BENCH_KEYS)
    audited = 0
    for fn in sorted(os.listdir(bench_dir)):
        if not fn.endswith(".py"):
            continue
        with open(os.path.join(bench_dir, fn)) as f:
            tree = ast.parse(f.read(), filename=fn)
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and ((isinstance(node.func, ast.Name)
                          and node.func.id == "bench_record")
                         or (isinstance(node.func, ast.Attribute)
                             and node.func.attr == "bench_record"))):
                continue
            kw = {k.arg for k in node.keywords if k.arg is not None}
            audited += 1
            derived = any(
                k.arg == "derived"
                and isinstance(k.value, ast.Constant) and k.value.value
                for k in node.keywords)
            if derived:
                continue
            # **extra splats may carry extras, but the required set must be
            # explicit at every call site so the audit stays static
            assert not (required - kw), \
                f"{fn}:{node.lineno}: bench_record missing explicit " \
                f"required keys {sorted(required - kw)}"
    assert audited >= 10, f"audit only found {audited} call sites"
