"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd,bq,bk", [
    (1, 4, 4, 128, 64, 64, 64),     # MHA
    (2, 8, 2, 256, 64, 64, 128),    # GQA
    (1, 16, 1, 128, 128, 32, 64),   # MQA
    (2, 4, 4, 192, 32, 64, 96),     # non-pow2 seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, B, H, KV, S, hd, bq, bk, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,T,hd,bk", [
    (2, 8, 2, 512, 64, 128),
    (1, 4, 4, 1024, 128, 256),
    (3, 16, 4, 256, 64, 64),
])
def test_decode_attention_sweep(dtype, B, H, KV, T, hd, bk):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, KV, T, hd), dtype)
    vc = jax.random.normal(ks[2], (B, KV, T, hd), dtype)
    length = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = ops.decode_attention(q, kc, vc, length, block_k=bk)
    want = ref.decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("Q,G,D,k,bq,bg", [
    (64, 512, 64, 8, 32, 128),
    (128, 1024, 32, 16, 128, 256),
    (32, 256, 128, 4, 32, 64),
])
def test_reid_topk_sweep(Q, G, D, k, bq, bg):
    ks = jax.random.split(KEY, 2)
    q = jax.random.normal(ks[0], (Q, D))
    g = jax.random.normal(ks[1], (G, D))
    sv, si = ops.reid_topk(q, g, k, block_q=bq, block_g=bg)
    rv, ri = ref.reid_topk_ref(q, g, k)
    np.testing.assert_allclose(sv, rv, rtol=1e-5, atol=1e-5)
    # indices: permutation-tolerant on ties — compare the score multiset
    np.testing.assert_allclose(np.sort(sv, 1), np.sort(rv, 1), rtol=1e-5)
    # gathered scores must match the claimed scores
    got = np.take_along_axis(np.asarray(q @ g.T), np.asarray(si), 1)
    np.testing.assert_allclose(got, sv, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,L,D,N,chunk,bd", [
    (2, 128, 64, 16, 32, 32),
    (1, 256, 128, 8, 64, 64),
    (2, 64, 32, 4, 64, 16),
])
def test_mamba_scan_sweep(B, L, D, N, chunk, bd):
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (B, L, D)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D))) * 0.1
    Bm = jax.random.normal(ks[2], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (D, N)) * 0.3)
    y = ops.mamba_scan(u, dt, Bm, Cm, A, chunk=chunk, block_d=bd)
    want, _ = ref.mamba_scan_ref(u, dt, Bm, Cm, A, jnp.zeros((B, D, N)))
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128]), st.sampled_from([32, 64]),
       st.booleans())
def test_flash_attention_property(B, S, hd, causal):
    """Property: kernel == oracle across hypothesis-drawn shapes."""
    ks = jax.random.split(jax.random.PRNGKey(B * S + hd), 3)
    H = KV = 2
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_model_blockwise_matches_kernel_semantics():
    """The pure-JAX model attention and the Pallas kernel agree (same math)."""
    from repro.configs import get_smoke_config
    from repro.models import attention as mattn

    cfg = get_smoke_config("yi_6b")
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 64, cfg.num_padded_heads, cfg.num_kv_heads, cfg.head_dim
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out_model = mattn.blockwise_attention(q, k, v, cfg, causal=True)
    out_kernel = ops.flash_attention(
        q.transpose(0, 2, 1, 3),
        jnp.take(k, mattn.kv_map(cfg), axis=2).transpose(0, 2, 1, 3),
        jnp.take(v, mattn.kv_map(cfg), axis=2).transpose(0, 2, 1, 3),
        causal=True, block_q=32, block_k=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out_model, out_kernel, rtol=2e-5, atol=2e-5)


def test_balanced_causal_schedule_matches_masked():
    from repro.configs import get_smoke_config
    from repro.models import attention as mattn

    cfg = get_smoke_config("deepseek_7b")
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 64, cfg.num_padded_heads, cfg.num_kv_heads, cfg.head_dim
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    a = mattn.blockwise_attention(q, k, v, cfg, causal=True, causal_skip=False)
    b = mattn.blockwise_attention(q, k, v, cfg, causal=True, causal_skip=True)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
