"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd,bq,bk", [
    (1, 4, 4, 128, 64, 64, 64),     # MHA
    (2, 8, 2, 256, 64, 64, 128),    # GQA
    (1, 16, 1, 128, 128, 32, 64),   # MQA
    (2, 4, 4, 192, 32, 64, 96),     # non-pow2 seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, B, H, KV, S, hd, bq, bk, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,T,hd,bk", [
    (2, 8, 2, 512, 64, 128),
    (1, 4, 4, 1024, 128, 256),
    (3, 16, 4, 256, 64, 64),
])
def test_decode_attention_sweep(dtype, B, H, KV, T, hd, bk):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, KV, T, hd), dtype)
    vc = jax.random.normal(ks[2], (B, KV, T, hd), dtype)
    length = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = ops.decode_attention(q, kc, vc, length, block_k=bk)
    want = ref.decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("Q,G,D,k,bq,bg", [
    (64, 512, 64, 8, 32, 128),
    (128, 1024, 32, 16, 128, 256),
    (32, 256, 128, 4, 32, 64),
    (33, 517, 16, 5, 32, 128),      # ragged: internal padding both axes
    (7, 70, 8, 3, 128, 512),        # smaller than one block on both axes
    (1, 1, 64, 1, 128, 512),
])
def test_reid_topk_sweep(Q, G, D, k, bq, bg):
    ks = jax.random.split(KEY, 2)
    q = jax.random.normal(ks[0], (Q, D))
    g = jax.random.normal(ks[1], (G, D))
    sv, si = ops.reid_topk(q, g, k, block_q=bq, block_g=bg)
    rv, ri = ref.reid_topk_ref(q, g, k)
    np.testing.assert_allclose(sv, rv, rtol=1e-5, atol=1e-5)
    # indices: permutation-tolerant on ties — compare the score multiset
    np.testing.assert_allclose(np.sort(sv, 1), np.sort(rv, 1), rtol=1e-5)
    # gathered scores must match the claimed scores
    got = np.take_along_axis(np.asarray(q @ g.T), np.asarray(si), 1)
    np.testing.assert_allclose(got, sv, rtol=1e-5, atol=1e-5)


def test_reid_topk_k_exceeds_gallery():
    """k > G: real entries first, padding surfaces as (NEG_INF, -1)."""
    ks = jax.random.split(KEY, 2)
    q = jax.random.normal(ks[0], (5, 16))
    g = jax.random.normal(ks[1], (3, 16))
    sv, si = ops.reid_topk(q, g, 8)
    rv, ri = ref.reid_topk_ref(q, g, 3)
    np.testing.assert_allclose(sv[:, :3], rv, rtol=1e-5, atol=1e-5)
    assert (np.asarray(si)[:, 3:] == -1).all()
    assert (np.asarray(sv)[:, 3:] < -1e29).all()


def test_reid_topk_masked_matches_ref():
    """Segment-masked variant == oracle on a mixed (cam, frame) batch."""
    rng = np.random.default_rng(3)
    Q, G, C, D, k = 11, 83, 6, 32, 4
    q = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(G, D)), jnp.float32)
    q_frame = jnp.asarray(rng.integers(0, 4, Q), jnp.int32)
    gal_cam = jnp.asarray(rng.integers(0, C, G), jnp.int32)
    gal_frame = jnp.asarray(rng.integers(0, 4, G), jnp.int32)
    adm = jnp.asarray(rng.random((Q, C)) < 0.5)
    sv, si = ops.reid_topk_masked(q, q_frame, adm, g, gal_cam, gal_frame, k)
    rv, ri = ref.reid_topk_masked_ref(q, q_frame, adm, g, gal_cam, gal_frame, k)
    np.testing.assert_allclose(sv, rv, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(si, ri)


def test_reid_topk_segments_matches_ref():
    """Segment-ID variant == oracle on a mixed (cam, segment) batch."""
    rng = np.random.default_rng(13)
    Q, G, C, D, k = 11, 83, 6, 32, 4
    q = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(G, D)), jnp.float32)
    q_seg = jnp.asarray(rng.integers(0, 4, Q), jnp.int32)
    gal_cam = jnp.asarray(rng.integers(0, C, G), jnp.int32)
    gal_seg = jnp.asarray(rng.integers(0, 4, G), jnp.int32)
    adm = jnp.asarray(rng.random((Q, C)) < 0.5)
    sv, si = ops.reid_topk_segments(q, q_seg, adm, g, gal_cam, gal_seg, k)
    rv, ri = ref.reid_topk_segments_ref(q, q_seg, adm, g, gal_cam, gal_seg, k)
    np.testing.assert_allclose(sv, rv, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(si, ri)


def test_reid_topk_segments_relabel_bit_identical_to_masked():
    """An injective frame -> segment relabeling changes NOTHING: same
    masked score matrix in, so the kernel's tie-breaks produce bit-identical
    (values, indices).  This is the consolidation plane's trace-identity
    contract — integer-valued features force exact float32 ties so the
    comparison is bit-for-bit, not allclose."""
    rng = np.random.default_rng(29)
    Q, G, C, D, k = 17, 131, 5, 8, 3
    q = jnp.asarray(rng.integers(0, 2, (Q, D)), jnp.float32)
    g = jnp.asarray(rng.integers(0, 2, (G, D)), jnp.float32)
    frames = np.array([3, 11, 40, 97], np.int32)       # sparse frame ids
    q_frame = frames[rng.integers(0, 4, Q)]
    gal_frame = frames[rng.integers(0, 4, G)]
    gal_cam = jnp.asarray(rng.integers(0, C, G), jnp.int32)
    adm = jnp.asarray(rng.random((Q, C)) < 0.6)
    # the RoundPlan relabeling: sorted unique frames -> compact segment ids
    seg_of = {int(f): s for s, f in enumerate(sorted(set(frames)))}
    q_seg = np.array([seg_of[int(f)] for f in q_frame], np.int32)
    gal_seg = np.array([seg_of[int(f)] for f in gal_frame], np.int32)
    msv, msi = ops.reid_topk_masked(
        q, jnp.asarray(q_frame), adm, g, gal_cam, jnp.asarray(gal_frame), k)
    ssv, ssi = ops.reid_topk_segments(
        q, jnp.asarray(q_seg), adm, g, gal_cam, jnp.asarray(gal_seg), k)
    np.testing.assert_array_equal(np.asarray(msv), np.asarray(ssv))
    np.testing.assert_array_equal(np.asarray(msi), np.asarray(ssi))


def test_reid_topk_tiles_matches_ref():
    """Tile-masked variant == oracle on a mixed (segment, fused-cell) batch
    — including unlabeled gallery rows (``gal_ct == -1``), which must match
    nothing rather than wrap into cell C*T*T - 1."""
    rng = np.random.default_rng(41)
    Q, G, C, T, D, k = 11, 83, 6, 3, 32, 4
    TT = T * T
    q = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(G, D)), jnp.float32)
    q_seg = jnp.asarray(rng.integers(0, 4, Q), jnp.int32)
    gal_seg = jnp.asarray(rng.integers(0, 4, G), jnp.int32)
    gal_cam = rng.integers(0, C, G)
    gal_ct = jnp.asarray(
        np.where(rng.random(G) < 0.15, -1,
                 gal_cam * TT + rng.integers(0, TT, G)), jnp.int32)
    adm_ct = jnp.asarray(rng.random((Q, C * TT)) < 0.4)
    sv, si = ops.reid_topk_tiles(q, q_seg, adm_ct, g, gal_ct, gal_seg, k)
    rv, ri = ref.reid_topk_tiles_ref(q, q_seg, adm_ct, g, gal_ct, gal_seg, k)
    np.testing.assert_allclose(sv, rv, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(si, ri)
    # every unlabeled row stayed invisible: no claimed index points at one
    unlabeled = set(np.flatnonzero(np.asarray(gal_ct) == -1).tolist())
    assert not (set(np.asarray(si).ravel().tolist()) - {-1}) & unlabeled


def test_reid_topk_tiles_all_admitted_bit_identical_to_segments():
    """The tile plane's trace-identity contract: with every tile of every
    admitted camera open (``admit_ct = repeat(admit, T*T)``) the tile kernel
    is BIT-identical to ``reid_topk_segments`` — same flat-argmin
    tie-breaks, same (NEG_INF, -1) sentinels.  Integer-valued features force
    exact float32 ties so the comparison is bit-for-bit, not allclose."""
    rng = np.random.default_rng(53)
    Q, G, C, T, D, k = 17, 131, 5, 4, 8, 3
    TT = T * T
    q = jnp.asarray(rng.integers(0, 2, (Q, D)), jnp.float32)
    g = jnp.asarray(rng.integers(0, 2, (G, D)), jnp.float32)
    q_seg = jnp.asarray(rng.integers(0, 4, Q), jnp.int32)
    gal_seg = jnp.asarray(rng.integers(0, 4, G), jnp.int32)
    gal_cam = rng.integers(0, C, G)
    gal_tile = rng.integers(0, TT, G)
    gal_ct = jnp.asarray(gal_cam * TT + gal_tile, jnp.int32)
    adm = rng.random((Q, C)) < 0.6
    adm_ct = jnp.asarray(np.repeat(adm, TT, axis=1))
    ssv, ssi = ops.reid_topk_segments(
        q, q_seg, jnp.asarray(adm), g, jnp.asarray(gal_cam, jnp.int32),
        gal_seg, k)
    tsv, tsi = ops.reid_topk_tiles(q, q_seg, adm_ct, g, gal_ct, gal_seg, k)
    np.testing.assert_array_equal(np.asarray(ssv), np.asarray(tsv))
    np.testing.assert_array_equal(np.asarray(ssi), np.asarray(tsi))
    # and closing one camera's tiles is exactly closing the camera: the
    # fused-cell mask degrades to the camera mask it was built from
    adm2 = adm.copy()
    adm2[:, 2] = False
    adm_ct2 = np.repeat(adm, TT, axis=1)
    adm_ct2[:, 2 * TT:3 * TT] = False
    s2 = ops.reid_topk_segments(q, q_seg, jnp.asarray(adm2), g,
                                jnp.asarray(gal_cam, jnp.int32), gal_seg, k)
    t2 = ops.reid_topk_tiles(q, q_seg, jnp.asarray(adm_ct2), g, gal_ct,
                             gal_seg, k)
    np.testing.assert_array_equal(np.asarray(s2[0]), np.asarray(t2[0]))
    np.testing.assert_array_equal(np.asarray(s2[1]), np.asarray(t2[1]))


def test_reid_topk_tiles_fully_masked_surfaces_sentinels():
    """All-closed admission and all-unlabeled galleries both rank every row
    to the kernels' (NEG_INF, -1) padding convention."""
    rng = np.random.default_rng(59)
    Q, G, C, T, D, k = 5, 37, 4, 2, 16, 2
    TT = T * T
    q = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(G, D)), jnp.float32)
    q_seg = jnp.zeros(Q, jnp.int32)
    gal_seg = jnp.zeros(G, jnp.int32)
    gal_ct = jnp.asarray(rng.integers(0, C * TT, G), jnp.int32)
    closed = jnp.zeros((Q, C * TT), bool)
    sv, si = ops.reid_topk_tiles(q, q_seg, closed, g, gal_ct, gal_seg, k)
    assert (np.asarray(si) == -1).all() and (np.asarray(sv) < -1e29).all()
    open_ct = jnp.ones((Q, C * TT), bool)
    unlabeled = jnp.full(G, -1, jnp.int32)
    sv, si = ops.reid_topk_tiles(q, q_seg, open_ct, g, unlabeled, gal_seg, k)
    assert (np.asarray(si) == -1).all() and (np.asarray(sv) < -1e29).all()


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 24), st.integers(0, 70), st.integers(2, 5),
       st.integers(1, 4), st.booleans())
def test_reid_rank_parity_property(Q, G, C, k, ties):
    """Property (ragged Q/G, ties, empty galleries): the Pallas kernel in
    interpret mode, the ref.py oracle, and the engine's match outcome all
    agree.  Tie cases use integer-valued features so float32 scores are
    exact and index tie-breaking is comparable bit-for-bit."""
    from repro.runtime.engine import rank_round

    rng = np.random.default_rng(100_000 + Q * 1000 + G * 10 + C + k)
    D = 8
    draw = (lambda s: rng.integers(0, 2, s).astype(np.float32)) if ties \
        else (lambda s: rng.normal(size=s).astype(np.float32))
    qf, gf = draw((Q, D)), draw((G, D))

    # -- plain kernel vs oracle ------------------------------------------
    sv, si = ops.reid_topk(jnp.asarray(qf), jnp.asarray(gf), k)
    if G == 0:
        assert (np.asarray(si) == -1).all()
        assert (np.asarray(sv) < -1e29).all()
    else:
        kk = min(k, G)
        rv, ri = ref.reid_topk_ref(jnp.asarray(qf), jnp.asarray(gf), kk)
        np.testing.assert_allclose(np.asarray(sv)[:, :kk], rv,
                                   rtol=1e-5, atol=1e-5)
        if ties:
            np.testing.assert_array_equal(np.asarray(si)[:, :kk], ri)
        assert (np.asarray(si)[:, kk:] == -1).all()

    # -- masked kernel vs oracle vs the engine's match path --------------
    q_frame = rng.integers(0, 3, Q).astype(np.int32)
    gal_cam = rng.integers(0, C, G).astype(np.int32)
    gal_frame = rng.integers(0, 3, G).astype(np.int32)
    adm = rng.random((Q, C)) < 0.6
    thresh = 0.6
    if G > 0:
        kk = min(k, G)
        msv, msi = ops.reid_topk_masked(
            jnp.asarray(qf), jnp.asarray(q_frame), jnp.asarray(adm),
            jnp.asarray(gf), jnp.asarray(gal_cam), jnp.asarray(gal_frame), kk)
        rmv, rmi = ref.reid_topk_masked_ref(
            jnp.asarray(qf), jnp.asarray(q_frame), jnp.asarray(adm),
            jnp.asarray(gf), jnp.asarray(gal_cam), jnp.asarray(gal_frame), kk)
        np.testing.assert_allclose(msv, rmv, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(msi, rmi)
        # the segment-ID entry under the round-scoped relabeling is
        # bit-identical to the frame-tag variant (consolidation contract)
        seg_of = {f: s for s, f in enumerate(sorted(set(q_frame) |
                                                    set(gal_frame)))}
        ssv, ssi = ops.reid_topk_segments(
            jnp.asarray(qf),
            jnp.asarray([seg_of[f] for f in q_frame], jnp.int32),
            jnp.asarray(adm), jnp.asarray(gf), jnp.asarray(gal_cam),
            jnp.asarray([seg_of[f] for f in gal_frame], jnp.int32), kk)
        np.testing.assert_array_equal(np.asarray(msv), np.asarray(ssv))
        np.testing.assert_array_equal(np.asarray(msi), np.asarray(ssi))
        # and the tile entry with every tile open degrades to the segment
        # entry bit-for-bit (the sub-frame plane's all-admitted contract)
        TT = 4
        gal_ct = jnp.asarray(gal_cam * TT + rng.integers(0, TT, G), jnp.int32)
        tsv, tsi = ops.reid_topk_tiles(
            jnp.asarray(qf),
            jnp.asarray([seg_of[f] for f in q_frame], jnp.int32),
            jnp.asarray(np.repeat(adm, TT, axis=1)), jnp.asarray(gf),
            gal_ct, jnp.asarray([seg_of[f] for f in gal_frame], jnp.int32),
            kk)
        np.testing.assert_array_equal(np.asarray(msv), np.asarray(tsv))
        np.testing.assert_array_equal(np.asarray(msi), np.asarray(tsi))

    (matched, match_cam, match_emb, topk_val, topk_idx, topk_cam,
     topk_frame) = (
        np.asarray(a) for a in rank_round(
        jnp.asarray(qf), jnp.asarray(q_frame), jnp.asarray(adm),
        jnp.asarray(gf), jnp.asarray(gal_cam), jnp.asarray(gal_frame), thresh))
    best_val, best_idx = topk_val[:, 0], topk_idx[:, 0]
    # numpy mirror of the pre-device host ranking loop
    for i in range(Q):
        valid = adm[i, gal_cam] & (gal_frame == q_frame[i]) if G else \
            np.zeros(0, bool)
        d = np.where(valid, 1.0 - gf.astype(np.float32) @ qf[i], 1e30) if G \
            else np.zeros(0)
        if not valid.any():
            assert not matched[i]
            # fully-masked rows surface the kernels' padding convention
            assert best_idx[i] == -1 and best_val[i] < -1e29
            continue
        j = int(np.argmin(d))
        assert bool(matched[i]) == bool(d[j] < thresh)
        if matched[i]:
            assert int(match_cam[i]) == int(gal_cam[j])
            np.testing.assert_allclose(match_emb[i], gf[j], rtol=1e-6)


@pytest.mark.parametrize("B,L,D,N,chunk,bd", [
    (2, 128, 64, 16, 32, 32),
    (1, 256, 128, 8, 64, 64),
    (2, 64, 32, 4, 64, 16),
])
def test_mamba_scan_sweep(B, L, D, N, chunk, bd):
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (B, L, D)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D))) * 0.1
    Bm = jax.random.normal(ks[2], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (D, N)) * 0.3)
    y = ops.mamba_scan(u, dt, Bm, Cm, A, chunk=chunk, block_d=bd)
    want, _ = ref.mamba_scan_ref(u, dt, Bm, Cm, A, jnp.zeros((B, D, N)))
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128]), st.sampled_from([32, 64]),
       st.booleans())
def test_flash_attention_property(B, S, hd, causal):
    """Property: kernel == oracle across hypothesis-drawn shapes."""
    ks = jax.random.split(jax.random.PRNGKey(B * S + hd), 3)
    H = KV = 2
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_model_blockwise_matches_kernel_semantics():
    """The pure-JAX model attention and the Pallas kernel agree (same math)."""
    from repro.configs import get_smoke_config
    from repro.models import attention as mattn

    cfg = get_smoke_config("yi_6b")
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 64, cfg.num_padded_heads, cfg.num_kv_heads, cfg.head_dim
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out_model = mattn.blockwise_attention(q, k, v, cfg, causal=True)
    out_kernel = ops.flash_attention(
        q.transpose(0, 2, 1, 3),
        jnp.take(k, mattn.kv_map(cfg), axis=2).transpose(0, 2, 1, 3),
        jnp.take(v, mattn.kv_map(cfg), axis=2).transpose(0, 2, 1, 3),
        causal=True, block_q=32, block_k=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out_model, out_kernel, rtol=2e-5, atol=2e-5)


def test_balanced_causal_schedule_matches_masked():
    from repro.configs import get_smoke_config
    from repro.models import attention as mattn

    cfg = get_smoke_config("deepseek_7b")
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 64, cfg.num_padded_heads, cfg.num_kv_heads, cfg.head_dim
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    a = mattn.blockwise_attention(q, k, v, cfg, causal=True, causal_skip=False)
    b = mattn.blockwise_attention(q, k, v, cfg, causal=True, causal_skip=True)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
