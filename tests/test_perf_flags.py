"""Beyond-paper optimization flags (EXPERIMENTS.md §Perf) — numerics must be
unchanged vs the paper-faithful baseline."""
import os
import subprocess
import sys
import textwrap

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import forward, init_params, lm_loss
from repro.perf import PerfFlags, perf_flags

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_causal_skip_identical_loss():
    cfg = get_smoke_config("deepseek_7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                          cfg.vocab_size)}
    l0, _ = lm_loss(params, batch, cfg, causal_skip=False)
    l1, _ = lm_loss(params, batch, cfg, causal_skip=True)
    np.testing.assert_allclose(l0, l1, rtol=1e-5)


def test_pad_vocab_preserves_distribution():
    """Padded-vocab softmax over real tokens == unpadded (same weights)."""
    cfg = get_smoke_config("whisper_tiny")
    cfg = dataclasses.replace(cfg, vocab_size=510)
    cfg_p = cfg.with_padded_vocab()
    assert cfg_p.vocab_size == 512 and cfg_p.real_vocab_size == 510
    params = init_params(cfg, jax.random.PRNGKey(0))
    # embed the unpadded params into the padded shapes (pad rows arbitrary)
    pp = jax.tree.map(lambda x: x, params)
    emb = params["embed"]
    pp["embed"] = dict(emb)
    pp["embed"]["tok"] = jnp.pad(emb["tok"], ((0, 2), (0, 0)),
                                 constant_values=7.0)
    if "head" in emb:
        pp["embed"]["head"] = jnp.pad(emb["head"], ((0, 0), (0, 2)),
                                      constant_values=7.0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 510),
             "frames": jax.random.normal(jax.random.PRNGKey(2),
                                         (2, cfg.encoder_seq, cfg.d_model)) * 0.1}
    lg0, _ = forward(params, batch, cfg)
    lg1, _ = forward(pp, batch, cfg_p)
    assert float(lg1[..., 510:].max()) < -1e29
    sm0 = jax.nn.softmax(lg0.astype(jnp.float32), axis=-1)
    sm1 = jax.nn.softmax(lg1.astype(jnp.float32), axis=-1)[..., :510]
    np.testing.assert_allclose(sm0, sm1, atol=2e-5)
    l0, _ = lm_loss(params, batch, cfg)
    l1, _ = lm_loss(pp, batch, cfg_p)
    np.testing.assert_allclose(l0, l1, rtol=1e-4)


def test_master_weight_optimizer_matches_fp32():
    """bf16 params + fp32 master == fp32 params after a step (master path)."""
    from repro.optim import OptConfig, adamw_update, init_opt_state

    w32 = {"w": jnp.linspace(-1, 1, 64, dtype=jnp.float32)}
    w16 = {"w": w32["w"].astype(jnp.bfloat16)}
    g = {"w": jnp.sin(jnp.arange(64.0)) * 0.1}
    cfg = OptConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)
    o32 = init_opt_state(w32)
    o16 = init_opt_state(w16, master_weights=True)
    p32, _, _ = adamw_update(w32, g, o32, cfg)
    p16, o16n, _ = adamw_update(w16, {"w": g["w"].astype(jnp.bfloat16)}, o16, cfg)
    # master tracks the fp32 trajectory exactly (modulo bf16 grad rounding)
    np.testing.assert_allclose(o16n["master"]["w"], p32["w"], rtol=1e-2, atol=1e-4)
    assert p16["w"].dtype == jnp.bfloat16


def _run_sub(code: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_serve_flags_decode_equivalence_on_mesh():
    """serve_params_replicated + serve_seq_sharded_kv: decode logits match the
    single-device decode bit-for-bit (fp32) on a 4x2 mesh."""
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.shapes import ShapeSpec
        from repro.launch.steps import build_decode_step
        from repro.models import init_params, init_decode_state, decode_step
        from repro.parallel.sharding import SINGLE_POD_RULES, mesh_context
        from repro.perf import PerfFlags, perf_flags

        # phi3 smoke: kv heads not TP-divisible -> exercises seq-sharded KV
        cfg = dataclasses.replace(get_smoke_config("phi3_medium_14b"),
                                  dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, T = 8, 64
        state = init_decode_state(cfg, B, T)
        tok = jax.random.randint(jax.random.PRNGKey(1), (B,), 0, cfg.vocab_size)
        ref, _ = decode_step(params, state, tok, cfg)

        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(4, 2)
        flags = PerfFlags(serve_params_replicated=True, serve_seq_sharded_kv=True)
        with perf_flags(flags), mesh_context(mesh, SINGLE_POD_RULES):
            step, _ = build_decode_step(cfg, mesh, SINGLE_POD_RULES,
                                        ShapeSpec("d", "decode", T, B))
            out, _ = step(params, state, tok)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-4, err
        print("serve-flags decode equivalence ok", err)
    """)


def test_moe_tp_dispatch_flag_equivalence():
    _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_block, init_moe
        from repro.parallel.sharding import SINGLE_POD_RULES, mesh_context
        from repro.perf import PerfFlags, perf_flags

        cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b"),
                                  capacity_factor=8.0)
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(4, 2)
        p, _ = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                              jnp.float32)
        out_local, _, _ = moe_block(p, x, cfg)
        shard = (
            {"router": NamedSharding(mesh, P()),
             "wi": NamedSharding(mesh, P("data", None, "model")),
             "wg": NamedSharding(mesh, P("data", None, "model")),
             "wo": NamedSharding(mesh, P("data", "model", None))},
            NamedSharding(mesh, P("data", None, None)))
        with perf_flags(PerfFlags(moe_tp_dispatch=True)), \\
             mesh_context(mesh, SINGLE_POD_RULES):
            f = jax.jit(lambda p, x: moe_block(p, x, cfg), in_shardings=shard)
            out, _, _ = f(p, x)
        rel = float(jnp.abs(out_local - out).max() / jnp.abs(out_local).max())
        assert rel < 2e-2, rel
        print("moe tp-dispatch flag equivalence ok", rel)
    """)
