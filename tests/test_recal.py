"""The §6 recalibration loop: trigger hysteresis, hot-swap semantics, and
the serve() wiring.  The fleet-vs-single epoch-boundary differential lives
in tests/test_sharded_engine.py (fleet_case_recalibration)."""
import numpy as np
import pytest

from repro.core.profiler import build_model
from repro.runtime.recal import (RecalibrationController, RecalibrationPolicy,
                                 match_log_source, visits_window_source)


def _toy_model(n_cams=4, epoch=0):
    """A tiny profiled model: a handful of 0->1 and 1->2 transitions."""
    ent = np.array([0, 0, 0, 1, 1, 1])
    cam = np.array([0, 1, 2, 0, 1, 2])
    t_in = np.array([0, 20, 40, 100, 120, 140])
    t_out = np.array([5, 25, 45, 105, 125, 145])
    return build_model(ent, cam, t_in, t_out, n_cams, epoch=epoch)


class _StubEngine:
    """The engine surface the controller touches: model, rescue matrix,
    swap_model, wall tick.  Records every swap instead of re-jitting."""

    def __init__(self, model):
        self.model = model
        self.C = model.n_cams
        self.rescue_pairs = np.zeros((self.C, self.C), np.int64)
        self.t = 0
        self.model_epoch = int(model.epoch)
        self.swap_times: list[int] = []

    def swap_model(self, model):
        self.model_epoch += 1
        self.model = model
        self.swap_times.append(self.t)
        return self.model_epoch


def _source_from_model_inputs():
    ent = np.array([0, 0, 1, 1])
    cam = np.array([0, 3, 0, 3])
    t_in = np.array([0, 30, 60, 95])
    t_out = np.array([5, 35, 65, 100])
    return lambda lo, hi: (ent, cam, t_in, t_out)


# ---------------------------------------------------------------------------
# trigger hysteresis (fake clock)
# ---------------------------------------------------------------------------

def test_borderline_score_does_not_thrash_swaps():
    """THE hysteresis regression: a drift score oscillating around the
    threshold must not re-trigger inside the cooldown — one swap, then
    silence until ``cooldown`` ticks have passed, then at most one more."""
    eng = _StubEngine(_toy_model())
    now = [0]
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=4, cooldown=100,
                            poll_every=5, window=200, reset_rescues=False)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: now[0])
    for t in range(0, 100, 5):
        now[0] = eng.t = t
        # oscillate around the threshold: rescues on a never-profiled pair
        # flip between 1 (score ~0.33 > 0.1) and 0 every poll
        eng.rescue_pairs[:] = 0
        eng.rescue_pairs[2, 3] = 1 if (t // 5) % 2 == 0 else 0
        eng.rescue_pairs[0, 2] = 4          # keeps min_rescues satisfied
        ctl.on_tick()
    assert eng.swap_times == [0], \
        f"cooldown violated: swaps at {eng.swap_times}"
    # cooldown expires -> the (still-high) score may trigger exactly once more
    for t in range(100, 160, 5):
        now[0] = eng.t = t
        eng.rescue_pairs[2, 3] = 1
        ctl.on_tick()
    assert eng.swap_times == [0, 100]
    assert eng.model_epoch == 2
    assert [e["epoch"] for e in ctl.events] == [1, 2]


def test_min_rescue_guard_blocks_noisy_small_samples():
    """One rescue on a never-profiled pair scores far above the threshold —
    but with fewer than min_rescues total events the trigger must not trust
    it (the small-sample guard)."""
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=8, cooldown=50,
                            poll_every=1)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    eng.rescue_pairs[2, 3] = 7              # high score, below the guard
    assert float(ctl.score().max()) > p.drift_threshold
    for t in range(30):
        eng.t = t
        ctl.on_tick()
    assert eng.swap_times == []
    eng.rescue_pairs[2, 3] = 8              # guard satisfied -> fires
    eng.t = 30
    ctl.on_tick()
    assert eng.swap_times == [30]


def test_poll_cadence_and_score_history():
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(poll_every=10)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    for t in range(0, 35):
        eng.t = t
        ctl.on_tick()
    assert [pp["t"] for pp in ctl.polls] == [0, 10, 20, 30]
    assert ctl.polls.maxlen is not None     # bounded on long-running engines
    assert all(pp["score"] == 0.0 and pp["rescues"] == 0 for pp in ctl.polls)


def test_rescue_reset_after_swap_rearms_the_trigger():
    """reset_rescues=True: the swap consumes the evidence — the same matrix
    must not re-trigger against the new model once the cooldown passes."""
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=2, cooldown=10,
                            poll_every=1, reset_rescues=True)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    eng.rescue_pairs[2, 3] = 5
    eng.t = 0
    ctl.on_tick()
    assert eng.swap_times == [0]
    assert eng.rescue_pairs.sum() == 0      # evidence consumed
    for t in range(1, 40):                  # far past the cooldown
        eng.t = t
        ctl.on_tick()
    assert eng.swap_times == [0], "re-triggered without fresh rescues"


def test_empty_window_skips_the_swap():
    """A tripped trigger with nothing to re-profile from (empty visit
    window) must not swap in a degenerate model."""
    eng = _StubEngine(_toy_model())
    z = np.zeros(0, np.int64)
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=1, cooldown=1,
                            poll_every=1)
    ctl = RecalibrationController(eng, lambda lo, hi: (z, z, z, z), p,
                                  clock=lambda: eng.t)
    eng.rescue_pairs[2, 3] = 3
    assert ctl.on_tick() is None
    assert eng.swap_times == [] and ctl.events == []


# ---------------------------------------------------------------------------
# engine hot-swap semantics (the real engine)
# ---------------------------------------------------------------------------

def _mini_world():
    from conftest import make_serving_world
    return make_serving_world(n_entities=60, horizon=240, seed=3, n_queries=2)


def _drive(eng, world, t_lo, t_hi, trace=None):
    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    for t in range(t_lo, t_hi):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        eng.tick(record_trace=trace)


def test_swap_model_keeps_in_flight_queries_and_stamps_epochs():
    from repro import api as rexcam
    from repro.core.profiler import build_model

    world = _mini_world()
    vis, feats = world["vis"], world["feats"]
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       geo_adj=world["net"].geo_adjacent)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    trace = []
    _drive(eng, world, t0, t0 + 30, trace)
    pre = {qid: (q.f_q, q.c_q, q.f_curr, q.phase, len(q.matches))
           for qid, q in eng.queries.items()}
    fresh = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, vis.n_cams,
                        time_limit=150)
    assert eng.swap_model(fresh) == 1
    assert eng.model_epoch == 1 and int(eng.model.epoch) == 1
    assert eng.model_swaps == [(t0 + 30, 1)]
    # in-flight queries survived the swap untouched
    assert {qid: (q.f_q, q.c_q, q.f_curr, q.phase, len(q.matches))
            for qid, q in eng.queries.items()} == pre
    _drive(eng, world, t0 + 30, t0 + 60, trace)
    epochs = {r["epoch"] for r in trace}
    assert epochs == {0, 1}, f"trace must span the swap, got {epochs}"
    # epoch is monotone along the trace: no round ran under a stale M
    seen = [r["epoch"] for r in trace]
    assert seen == sorted(seen)


def test_swap_model_mid_round_raises():
    """The atomicity contract: one round sees ONE model — swapping from
    inside the round (here: from embed_fn) must fail loudly."""
    from repro import api as rexcam

    world = _mini_world()
    vis, feats = world["vis"], world["feats"]
    caught = []

    def embed_fn(x):
        try:
            eng.swap_model(world["model"])
        except RuntimeError as e:
            caught.append(str(e))
        return x

    eng = rexcam.serve(world["model"], embed_fn=embed_fn,
                       geo_adj=world["net"].geo_adjacent)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    _drive(eng, world, t0, t0 + 20)
    assert caught, "embed_fn never ran — world too small to admit anything"
    assert "mid-round" in caught[0]
    assert eng.model_epoch == 0         # nothing swapped


def test_swap_model_shape_mismatch_raises():
    from repro import api as rexcam

    world = _mini_world()
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x)
    vis = world["vis"]
    with pytest.raises(ValueError, match="n_bins"):
        eng.swap_model(build_model(vis.ent, vis.cam, vis.t_in, vis.t_out,
                                   vis.n_cams, n_bins=64))
    bad_c = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out,
                        vis.n_cams + 1)
    with pytest.raises(ValueError):
        eng.swap_model(bad_c)


# ---------------------------------------------------------------------------
# trajectory sources
# ---------------------------------------------------------------------------

def test_visits_window_source_slices_active_visits():
    from repro.core.simulate import Visits

    vis = Visits(np.array([0, 0, 1]), np.array([0, 1, 2]),
                 np.array([0, 50, 90]), np.array([10, 60, 95]), 100, 3)
    src = visits_window_source(vis)
    ent, cam, t_in, t_out = src(40, 80)
    assert ent.tolist() == [0] and cam.tolist() == [1]
    ent, _, _, _ = src(0, 100)
    assert len(ent) == 3


def test_match_log_source_rebuilds_query_trajectories():
    """The engine's own sightings (submit anchor + matches) re-profile into
    a model whose transitions are exactly the tracked hops."""
    eng = _StubEngine(_toy_model())
    eng.sightings = [(0, 0, 10), (0, 1, 55), (0, 2, 99), (1, 0, 200)]
    src = match_log_source(eng)
    ent, cam, t_in, t_out = src(0, 150)
    assert ent.tolist() == [0, 0, 0] and cam.tolist() == [0, 1, 2]
    m = build_model(ent, cam, t_in, t_out, 4)
    assert float(m.counts[0, 1]) == 1.0 and float(m.counts[1, 2]) == 1.0
    ent, _, _, _ = src(300, 400)
    assert len(ent) == 0


def test_engine_sighting_log_grows_with_matches():
    from repro import api as rexcam

    world = _mini_world()
    vis, feats = world["vis"], world["feats"]
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       geo_adj=world["net"].geo_adjacent)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    assert len(eng.sightings) == len(q_vids)      # the submit anchors
    _drive(eng, world, t0, vis.horizon)
    n_matches = sum(len(q.matches) for q in eng.queries.values())
    assert n_matches > 0
    assert len(eng.sightings) == len(q_vids) + n_matches


# ---------------------------------------------------------------------------
# serve() wiring
# ---------------------------------------------------------------------------

def test_sighting_log_pruned_on_long_runs():
    """The sighting log is bounded: entries no recalibration window can
    still reach are dropped each tick (a serving engine runs forever)."""
    from repro import api as rexcam

    world = _mini_world()
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, retention=10)
    eng.sightings.extend((0, 0, f) for f in range(100))
    eng.t = 500
    eng.tick()
    assert len(eng.sightings) == 0          # all far behind t - 2*retention
    eng.sightings.append((0, 0, eng.t - 1))  # recent: survives
    eng.tick()
    assert len(eng.sightings) == 1


def test_api_serve_recalibrate_knob():
    from repro import api as rexcam
    from repro.runtime.recal import RecalibrationController

    world = _mini_world()
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x)
    assert eng.recal is None
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       recalibrate=True)
    assert isinstance(eng.recal, RecalibrationController)
    custom = RecalibrationPolicy(drift_threshold=.3)
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       recalibrate=custom,
                       visit_source=visits_window_source(world["vis"]))
    assert eng.recal.policy is custom
    with pytest.raises(TypeError):
        rexcam.serve(world["model"], embed_fn=lambda x: x, recalibrate=123)
    with pytest.raises(ValueError, match="visit_source"):
        rexcam.serve(world["model"], embed_fn=lambda x: x,
                     visit_source=visits_window_source(world["vis"]))
