"""The §6 recalibration loop: trigger hysteresis, hot-swap semantics, and
the serve() wiring.  The fleet-vs-single epoch-boundary differential lives
in tests/test_sharded_engine.py (fleet_case_recalibration)."""
import numpy as np
import pytest

from repro.core.profiler import build_model
from repro.runtime.recal import (RecalibrationController, RecalibrationPolicy,
                                 match_log_source, visits_window_source)


def _toy_model(n_cams=4, epoch=0):
    """A tiny profiled model: a handful of 0->1 and 1->2 transitions."""
    ent = np.array([0, 0, 0, 1, 1, 1])
    cam = np.array([0, 1, 2, 0, 1, 2])
    t_in = np.array([0, 20, 40, 100, 120, 140])
    t_out = np.array([5, 25, 45, 105, 125, 145])
    return build_model(ent, cam, t_in, t_out, n_cams, epoch=epoch)


class _StubEngine:
    """The engine surface the controller touches: model, rescue matrix,
    swap_model, wall tick.  Records every swap instead of re-jitting."""

    def __init__(self, model):
        self.model = model
        self.C = model.n_cams
        self.rescue_pairs = np.zeros((self.C, self.C), np.int64)
        self.t = 0
        self.model_epoch = int(model.epoch)
        self.swap_times: list[int] = []

    def swap_model(self, model):
        self.model_epoch += 1
        self.model = model
        self.swap_times.append(self.t)
        return self.model_epoch


def _source_from_model_inputs():
    ent = np.array([0, 0, 1, 1])
    cam = np.array([0, 3, 0, 3])
    t_in = np.array([0, 30, 60, 95])
    t_out = np.array([5, 35, 65, 100])
    return lambda lo, hi: (ent, cam, t_in, t_out)


# ---------------------------------------------------------------------------
# trigger hysteresis (fake clock)
# ---------------------------------------------------------------------------

def test_borderline_score_does_not_thrash_swaps():
    """THE hysteresis regression: a drift score oscillating around the
    threshold must not re-trigger inside the cooldown — one swap, then
    silence until ``cooldown`` ticks have passed, then at most one more."""
    eng = _StubEngine(_toy_model())
    now = [0]
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=4, cooldown=100,
                            poll_every=5, window=200, reset_rescues=False)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: now[0])
    for t in range(0, 100, 5):
        now[0] = eng.t = t
        # oscillate around the threshold: rescues on a never-profiled pair
        # flip between 1 (score ~0.33 > 0.1) and 0 every poll
        eng.rescue_pairs[:] = 0
        eng.rescue_pairs[2, 3] = 1 if (t // 5) % 2 == 0 else 0
        eng.rescue_pairs[0, 2] = 4          # keeps min_rescues satisfied
        ctl.on_tick()
    assert eng.swap_times == [0], \
        f"cooldown violated: swaps at {eng.swap_times}"
    # cooldown expires -> the (still-high) score may trigger exactly once more
    for t in range(100, 160, 5):
        now[0] = eng.t = t
        eng.rescue_pairs[2, 3] = 1
        ctl.on_tick()
    assert eng.swap_times == [0, 100]
    assert eng.model_epoch == 2
    assert [e["epoch"] for e in ctl.events] == [1, 2]


def test_min_rescue_guard_blocks_noisy_small_samples():
    """One rescue on a never-profiled pair scores far above the threshold —
    but with fewer than min_rescues total events the trigger must not trust
    it (the small-sample guard)."""
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=8, cooldown=50,
                            poll_every=1)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    eng.rescue_pairs[2, 3] = 7              # high score, below the guard
    assert float(ctl.score().max()) > p.drift_threshold
    for t in range(30):
        eng.t = t
        ctl.on_tick()
    assert eng.swap_times == []
    eng.rescue_pairs[2, 3] = 8              # guard satisfied -> fires
    eng.t = 30
    ctl.on_tick()
    assert eng.swap_times == [30]


def test_poll_cadence_and_score_history():
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(poll_every=10)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    for t in range(0, 35):
        eng.t = t
        ctl.on_tick()
    assert [pp["t"] for pp in ctl.polls] == [0, 10, 20, 30]
    assert ctl.polls.maxlen is not None     # bounded on long-running engines
    assert all(pp["score"] == 0.0 and pp["rescues"] == 0 for pp in ctl.polls)


def test_rescue_reset_after_swap_rearms_the_trigger():
    """reset_rescues=True: the swap consumes the evidence — the same matrix
    must not re-trigger against the new model once the cooldown passes."""
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=2, cooldown=10,
                            poll_every=1, reset_rescues=True)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    eng.rescue_pairs[2, 3] = 5
    eng.t = 0
    ctl.on_tick()
    assert eng.swap_times == [0]
    assert eng.rescue_pairs.sum() == 0      # evidence consumed
    for t in range(1, 40):                  # far past the cooldown
        eng.t = t
        ctl.on_tick()
    assert eng.swap_times == [0], "re-triggered without fresh rescues"


def test_empty_window_skips_the_swap():
    """A tripped trigger with nothing to re-profile from (empty visit
    window) must not swap in a degenerate model."""
    eng = _StubEngine(_toy_model())
    z = np.zeros(0, np.int64)
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=1, cooldown=1,
                            poll_every=1)
    ctl = RecalibrationController(eng, lambda lo, hi: (z, z, z, z), p,
                                  clock=lambda: eng.t)
    eng.rescue_pairs[2, 3] = 3
    assert ctl.on_tick() is None
    assert eng.swap_times == [] and ctl.events == []


# ---------------------------------------------------------------------------
# row-targeted re-profiling: the merge-vs-rebuild bit-identity property
# ---------------------------------------------------------------------------

def _soak_windows(C=6, rows=(1, 4), seed=0):
    """Two visit windows sharing their NON-drifted traffic bit-for-bit:
    ``shared`` entities walk only the complement cameras, ``drift`` entities
    (departures AND exits) stay inside ``rows``.  Returns (window_a,
    window_b) as (ent, cam, t_in, t_out, tile_xy) tuples — the precondition
    under which merging B's re-profiled rows into A's model must equal a
    full rebuild on B."""
    rng = np.random.default_rng(seed)
    keep = [c for c in range(C) if c not in rows]

    def walk(eid, cams, n_hops, t0):
        e, c, ti, to, xy = [], [], [], [], []
        t = t0
        for _ in range(n_hops):
            e.append(eid)
            c.append(int(rng.choice(cams)))
            ti.append(t)
            to.append(t + int(rng.integers(1, 4)))
            xy.append(rng.uniform(0, 1, 2))
            t = to[-1] + int(rng.integers(2, 8))
        return e, c, ti, to, xy

    shared = [walk(e, keep, 5, e * 3) for e in range(6)]

    def window(drift_seed):
        drng = np.random.default_rng(drift_seed)
        parts = [list(map(list, s)) for s in shared]
        for e in range(6, 10):
            t = int(drng.integers(0, 10))
            ent_d, cam_d, ti_d, to_d, xy_d = [], [], [], [], []
            for _ in range(4):
                ent_d.append(e)
                cam_d.append(int(drng.choice(rows)))
                ti_d.append(t)
                to_d.append(t + int(drng.integers(1, 4)))
                xy_d.append(drng.uniform(0, 1, 2))
                t = to_d[-1] + int(drng.integers(2, 8))
            parts.append([ent_d, cam_d, ti_d, to_d, xy_d])
        ent = np.concatenate([p[0] for p in parts]).astype(np.int64)
        cam = np.concatenate([p[1] for p in parts]).astype(np.int64)
        t_in = np.concatenate([p[2] for p in parts]).astype(np.int64)
        t_out = np.concatenate([p[3] for p in parts]).astype(np.int64)
        xy = np.concatenate([np.asarray(p[4]).reshape(-1, 2) for p in parts])
        return ent, cam, t_in, t_out, xy

    return window(seed + 100), window(seed + 200)


def test_merge_reprofiled_rows_bit_identical_to_full_rebuild():
    """THE row-locality property (core.correlation.ROW_LOCAL_FIELDS):
    when the non-drifted rows' window contents are unchanged, splicing
    freshly profiled drifted rows into the prior model equals a full
    ``build_model`` rebuild on the new window — every field bit-for-bit,
    tile_admit rows and the epoch stamp included."""
    from repro.core.profiler import merge_reprofiled_rows

    C, R, T = 6, (1, 4), 4
    (ea, ca, ia, oa, xya), (eb, cb, ib, ob, xyb) = _soak_windows(C, R)
    old = build_model(ea, ca, ia, oa, C, n_bins=32, bin_width=2,
                      tile_xy=xya, tile_grid=T, epoch=4)
    full = build_model(eb, cb, ib, ob, C, n_bins=32, bin_width=2,
                       tile_xy=xyb, tile_grid=T, epoch=5)
    merged = merge_reprofiled_rows(old, eb, cb, ib, ob, R, tile_xy=xyb,
                                   epoch=5)
    for f in ("S", "exit_frac", "cdf", "f0", "entry", "counts",
              "tile_admit"):
        np.testing.assert_array_equal(
            np.asarray(getattr(merged, f)), np.asarray(getattr(full, f)),
            err_msg=f"field {f} diverged from the full rebuild")
    assert int(merged.epoch) == 5
    assert merged.bin_width == full.bin_width == 2
    # and the untouched rows really are the OLD arrays' rows
    keep = [c for c in range(C) if c not in R]
    np.testing.assert_array_equal(np.asarray(merged.S)[keep],
                                  np.asarray(old.S)[keep])
    np.testing.assert_array_equal(np.asarray(merged.tile_admit)[keep],
                                  np.asarray(old.tile_admit)[keep])


def test_merge_reprofiled_rows_without_tiles_carries_old_tile_rows():
    """A targeted re-profile WITHOUT tile positions (the controller's
    visit_source returns no tile_xy) must carry the incumbent learned
    masks wholesale — mirroring engine.swap_model's tile carry."""
    from repro.core.profiler import merge_reprofiled_rows

    C, R = 6, (1, 4)
    (ea, ca, ia, oa, xya), (eb, cb, ib, ob, _) = _soak_windows(C, R, seed=3)
    old = build_model(ea, ca, ia, oa, C, tile_xy=xya, tile_grid=4)
    merged = merge_reprofiled_rows(old, eb, cb, ib, ob, R)
    np.testing.assert_array_equal(np.asarray(merged.tile_admit),
                                  np.asarray(old.tile_admit))
    assert merged.tile_grid == 4 and merged.tile_learned
    # epoch defaults to the incumbent's (swap_model stamps the bump)
    assert int(merged.epoch) == int(old.epoch)


def test_merge_reprofiled_rows_validates_rows():
    from repro.core.profiler import merge_reprofiled_rows

    (ea, ca, ia, oa, _), _ = _soak_windows()
    old = build_model(ea, ca, ia, oa, 6)
    with pytest.raises(ValueError):
        merge_reprofiled_rows(old, ea, ca, ia, oa, [])
    with pytest.raises(ValueError):
        merge_reprofiled_rows(old, ea, ca, ia, oa, [0, 6])


def test_splice_rows_rejects_non_row_local_fields():
    from repro.core.correlation import splice_rows

    (ea, ca, ia, oa, _), _ = _soak_windows()
    old = build_model(ea, ca, ia, oa, 6)
    with pytest.raises(ValueError, match="not row-local"):
        splice_rows(old, [0], {"entry": np.zeros((1,))})
    with pytest.raises(ValueError, match="no 'tile_admit'"):
        splice_rows(old, [0], {"tile_admit": np.ones((1, 6, 16), bool)})


# ---------------------------------------------------------------------------
# the targeted controller (profiler call accounting + drifted-row selection)
# ---------------------------------------------------------------------------

def _targeted_ctl(rows_hot, thr=.1, row_threshold=None):
    """Stub engine + targeted controller with rescues concentrated on the
    given source rows (never-profiled pairs, so their score is high)."""
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(drift_threshold=thr, min_rescues=1, cooldown=1,
                            poll_every=1, targeted=True,
                            row_threshold=row_threshold)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    for r in rows_hot:
        eng.rescue_pairs[r, 3] = 5
    return eng, ctl


def test_targeted_recal_reprofiles_only_drifted_rows():
    eng, ctl = _targeted_ctl(rows_hot=[2])
    old = eng.model
    ev = ctl.on_tick()
    assert ev["mode"] == "targeted" and ev["row_ids"] == [2]
    assert ctl.targeted_swaps == 1 and ctl.full_rebuilds == 0
    assert ctl.rows_reprofiled == 1
    assert ctl.profile_wall > 0.0
    # untouched rows carry bit-exact; the hot row re-profiled from the
    # window (here: no 2->x transitions in the source, so row 2 zeroes out)
    keep = [0, 1, 3]
    np.testing.assert_array_equal(np.asarray(eng.model.S)[keep],
                                  np.asarray(old.S)[keep])
    src = _source_from_model_inputs()
    full = build_model(*src(0, 0), eng.C, n_bins=old.n_bins,
                       bin_width=old.bin_width)
    np.testing.assert_array_equal(np.asarray(eng.model.S)[2],
                                  np.asarray(full.S)[2])
    np.testing.assert_array_equal(np.asarray(eng.model.entry),
                                  np.asarray(full.entry))


def test_targeted_recal_row_threshold_widens_selection():
    """row_threshold below the trip threshold pulls mildly drifted rows
    into the same re-profile pass."""
    eng, ctl = _targeted_ctl(rows_hot=[0, 2], row_threshold=.01)
    ev = ctl.on_tick()
    assert ev["row_ids"] == [0, 2]
    assert ctl.rows_reprofiled == 2


def test_full_rebuild_books_every_row():
    eng = _StubEngine(_toy_model())
    p = RecalibrationPolicy(drift_threshold=.1, min_rescues=1, cooldown=1,
                            poll_every=1, targeted=False)
    ctl = RecalibrationController(eng, _source_from_model_inputs(), p,
                                  clock=lambda: eng.t)
    eng.rescue_pairs[2, 3] = 5
    ev = ctl.on_tick()
    assert ev["mode"] == "full" and ev["row_ids"] is None
    assert ev["rows"] == eng.C
    assert ctl.full_rebuilds == 1 and ctl.targeted_swaps == 0
    assert ctl.rows_reprofiled == eng.C
    assert ctl.profile_wall > 0.0


# ---------------------------------------------------------------------------
# engine hot-swap semantics (the real engine)
# ---------------------------------------------------------------------------

def _mini_world():
    from conftest import make_serving_world
    return make_serving_world(n_entities=60, horizon=240, seed=3, n_queries=2)


def _drive(eng, world, t_lo, t_hi, trace=None):
    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    for t in range(t_lo, t_hi):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        eng.tick(record_trace=trace)


def test_swap_model_keeps_in_flight_queries_and_stamps_epochs():
    from repro import api as rexcam
    from repro.core.profiler import build_model

    world = _mini_world()
    vis, feats = world["vis"], world["feats"]
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       geo_adj=world["net"].geo_adjacent)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    trace = []
    _drive(eng, world, t0, t0 + 30, trace)
    pre = {qid: (q.f_q, q.c_q, q.f_curr, q.phase, len(q.matches))
           for qid, q in eng.queries.items()}
    fresh = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, vis.n_cams,
                        time_limit=150)
    assert eng.swap_model(fresh) == 1
    assert eng.model_epoch == 1 and int(eng.model.epoch) == 1
    assert eng.model_swaps == [(t0 + 30, 1)]
    # in-flight queries survived the swap untouched
    assert {qid: (q.f_q, q.c_q, q.f_curr, q.phase, len(q.matches))
            for qid, q in eng.queries.items()} == pre
    _drive(eng, world, t0 + 30, t0 + 60, trace)
    epochs = {r["epoch"] for r in trace}
    assert epochs == {0, 1}, f"trace must span the swap, got {epochs}"
    # epoch is monotone along the trace: no round ran under a stale M
    seen = [r["epoch"] for r in trace]
    assert seen == sorted(seen)


def test_swap_model_mid_round_raises():
    """The atomicity contract: one round sees ONE model — swapping from
    inside the round (here: from embed_fn) must fail loudly."""
    from repro import api as rexcam

    world = _mini_world()
    vis, feats = world["vis"], world["feats"]
    caught = []

    def embed_fn(x):
        try:
            eng.swap_model(world["model"])
        except RuntimeError as e:
            caught.append(str(e))
        return x

    eng = rexcam.serve(world["model"], embed_fn=embed_fn,
                       geo_adj=world["net"].geo_adjacent)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    _drive(eng, world, t0, t0 + 20)
    assert caught, "embed_fn never ran — world too small to admit anything"
    assert "mid-round" in caught[0]
    assert eng.model_epoch == 0         # nothing swapped


def test_swap_model_shape_mismatch_raises():
    from repro import api as rexcam

    world = _mini_world()
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x)
    vis = world["vis"]
    with pytest.raises(ValueError, match="n_bins"):
        eng.swap_model(build_model(vis.ent, vis.cam, vis.t_in, vis.t_out,
                                   vis.n_cams, n_bins=64))
    bad_c = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out,
                        vis.n_cams + 1)
    with pytest.raises(ValueError):
        eng.swap_model(bad_c)


# ---------------------------------------------------------------------------
# trajectory sources
# ---------------------------------------------------------------------------

def test_visits_window_source_slices_active_visits():
    from repro.core.simulate import Visits

    vis = Visits(np.array([0, 0, 1]), np.array([0, 1, 2]),
                 np.array([0, 50, 90]), np.array([10, 60, 95]), 100, 3)
    src = visits_window_source(vis)
    ent, cam, t_in, t_out = src(40, 80)
    assert ent.tolist() == [0] and cam.tolist() == [1]
    ent, _, _, _ = src(0, 100)
    assert len(ent) == 3


def test_match_log_source_rebuilds_query_trajectories():
    """The engine's own sightings (submit anchor + matches) re-profile into
    a model whose transitions are exactly the tracked hops."""
    eng = _StubEngine(_toy_model())
    eng.sightings = [(0, 0, 10), (0, 1, 55), (0, 2, 99), (1, 0, 200)]
    src = match_log_source(eng)
    ent, cam, t_in, t_out = src(0, 150)
    assert ent.tolist() == [0, 0, 0] and cam.tolist() == [0, 1, 2]
    m = build_model(ent, cam, t_in, t_out, 4)
    assert float(m.counts[0, 1]) == 1.0 and float(m.counts[1, 2]) == 1.0
    ent, _, _, _ = src(300, 400)
    assert len(ent) == 0


def test_engine_sighting_log_grows_with_matches():
    from repro import api as rexcam

    world = _mini_world()
    vis, feats = world["vis"], world["feats"]
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       geo_adj=world["net"].geo_adjacent)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    assert len(eng.sightings) == len(q_vids)      # the submit anchors
    _drive(eng, world, t0, vis.horizon)
    n_matches = sum(len(q.matches) for q in eng.queries.values())
    assert n_matches > 0
    assert len(eng.sightings) == len(q_vids) + n_matches


# ---------------------------------------------------------------------------
# serve() wiring
# ---------------------------------------------------------------------------

def test_sighting_log_pruned_on_long_runs():
    """The sighting log is bounded: entries no recalibration window can
    still reach are dropped each tick (a serving engine runs forever)."""
    from repro import api as rexcam

    world = _mini_world()
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, retention=10)
    eng.sightings.extend((0, 0, f) for f in range(100))
    eng.t = 500
    eng.tick()
    assert len(eng.sightings) == 0          # all far behind t - 2*retention
    eng.sightings.append((0, 0, eng.t - 1))  # recent: survives
    eng.tick()
    assert len(eng.sightings) == 1


def test_api_serve_recalibrate_knob():
    from repro import api as rexcam
    from repro.runtime.recal import RecalibrationController

    world = _mini_world()
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x)
    assert eng.recal is None
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       recalibrate=True)
    assert isinstance(eng.recal, RecalibrationController)
    custom = RecalibrationPolicy(drift_threshold=.3)
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                       recalibrate=custom,
                       visit_source=visits_window_source(world["vis"]))
    assert eng.recal.policy is custom
    with pytest.raises(TypeError):
        rexcam.serve(world["model"], embed_fn=lambda x: x, recalibrate=123)
    with pytest.raises(ValueError, match="visit_source"):
        rexcam.serve(world["model"], embed_fn=lambda x: x,
                     visit_source=visits_window_source(world["vis"]))
