# Planted REX005 corpus: jit entry points without declared statics.
# rex-expect: REX005=2
from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def rank_undeclared(q, g, k):                # planted: `k` must be static
    return jnp.dot(q, g.T) * k


@partial(jax.jit, static_argnames=("k", "interpret"))
def rank_declared(q, g, k, interpret):       # declared: fine
    return jnp.dot(q, g.T) * k


def topk_body(scores, topk):
    return scores[:topk]


ranked = jax.jit(topk_body)                  # planted: `topk` must be static
ranked_ok = jax.jit(topk_body, static_argnames=("topk",))   # declared: fine
