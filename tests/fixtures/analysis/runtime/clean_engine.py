# Fully clean fixture: the discipline every rule asks for, in one file.
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from numpy.random import default_rng


@partial(jax.jit, static_argnames=("k",))
def rank_round(scores, k):
    return jnp.sort(scores)[:k]


class CleanEngine:
    def _round_body(self, frames, rng_seed):
        rng = default_rng(rng_seed)
        crops = np.stack([np.asarray(f) for f in frames])
        order = rng.permutation(len(frames))
        wanted = {int(i) for i in order[:2]}
        return [crops[i] for i in sorted(wanted)]
