# Planted REX001 corpus: heavy host-numpy inside hot-path round bodies.
# rex-expect: REX001=1
import numpy as np


class FakeEngine:
    def _round_body(self, feats):
        crops = np.asarray(feats)            # cheap marshalling: fine
        norms = np.linalg.norm(crops, axis=-1)   # planted: REX001 fires here
        order = np.sort(norms)               # rex: disable=REX001
        return crops, order

    def _skip_round(self, scores):  # rex: disable=REX001
        # def-level suppression covers the whole body
        return np.argmax(scores)

    def bookkeeping(self, scores):
        # not a hot-path function name: heavy numpy is allowed here
        return np.mean(scores)
