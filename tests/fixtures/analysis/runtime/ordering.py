# Planted REX004 corpus: unordered set iteration feeding placement.
# rex-expect: REX004=2


def place_workers(keys, owners):
    pending = set(keys)
    for k in pending:                        # planted: arbitrary order
        owners[k] = len(owners)
    for k in sorted(pending):                # sorted: fine
        owners[k] = len(owners)
    drained = [c for c in {2, 0, 1}]         # planted: set literal iterated
    replay = [c for c in sorted({2, 0, 1})]  # sorted: fine
    for k in enumerate(pending):             # rex: disable=REX004
        pass
    return drained, replay


def account(rounds: list):
    # a LIST named like the set above must not be tainted cross-scope
    pending = [r for r in rounds]
    for r in pending:                        # list iteration: fine
        yield r
