# Planted REX003 corpus: python control flow on traced values.
# rex-expect: REX003=2
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("k",))
def rank_static(scores, k):
    if k > 1:                                # static kwarg: fine
        scores = scores * 2.0
    if scores.shape[0] > 4:                  # shapes are python ints: fine
        scores = scores[:4]
    if scores > 0:                           # planted: branch on a tracer
        scores = scores + 1.0
    return jnp.sort(scores)[:k]


@jax.jit
def concretize(x):
    lead = len(x)                            # len() of a tracer is an int: fine
    if x is None:                            # identity test: fine
        return jnp.zeros(())
    flag = bool(x)                           # planted: concretizes the tracer
    return x * (lead + flag)
