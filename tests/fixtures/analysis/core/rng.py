# Planted REX002 corpus: unseeded / global RNG in trace-affecting code.
# rex-expect: REX002=3
import random

import numpy as np
from numpy.random import default_rng


def sample_replay(n):
    rng = default_rng()                      # planted: unseeded default_rng
    jitter = np.random.randint(0, 4)         # planted: legacy global RNG
    coin = random.random()                   # planted: stdlib global RNG
    keep = random.shuffle                    # bare reference, not a call: fine
    return rng, jitter, coin, keep


def sample_seeded(n, seed):
    rng = default_rng(seed)                  # seeded: fine
    noise = default_rng(0).normal(size=n)    # seeded: fine
    burn = np.random.permutation(n)          # rex: disable=REX002
    return rng, noise, burn
