# Clean under lint: file-level suppression silences every REX002 below.
# rex: disable-file=REX002
import random

from numpy.random import default_rng


def chaos_probe():
    # deliberate nondeterminism (a fault-injection helper would live here);
    # the file-level waiver above keeps the gate quiet
    return default_rng(), random.random()
