"""The transport & prefetch plane (runtime/transport.py).

Two layers of coverage:

  * a FAKE-CLOCK unit suite for the fetch-plane arithmetic — latency/jitter
    delivery windows, drop -> timeout -> retry-with-backoff schedules,
    dead-peer declaration and the ``on_dead`` signal, reorder, determinism,
    and the ``PrefetchPipeline``'s misspeculation accounting.  Injected
    latency advances a virtual clock (``manual_clock``), so seconds of
    modelled RTT cost microseconds of test time;
  * the fleet DIFFERENTIAL matrix on >= 8 fake CPU devices (in-process
    when available, else the flag-setting subprocess — same split as
    ``test_sharded_engine.py``): every transport/fault configuration must
    be TRACE-IDENTICAL to the single engine, because transport may only
    change WHEN a gallery block arrives, never WHAT is ranked.
"""
import numpy as np
import pytest

from test_sharded_engine import _fleet_case


def _fake(faults=None, **kw):
    from repro.runtime.transport import FakeRpcTransport, manual_clock

    clock, sleep = manual_clock()
    tr = FakeRpcTransport(faults or {}, clock=clock, sleep=sleep, **kw)
    return tr, clock


# ---------------------------------------------------------------------------
# fake-clock unit suite: delivery / timeout / retry / backoff arithmetic
# ---------------------------------------------------------------------------

def test_inproc_transport_is_immediate_and_zero_copy():
    from repro.runtime.transport import InProcTransport

    tr = InProcTransport()
    calls = []

    def payload():
        calls.append(1)
        return "block"

    h = tr.fetch_async("w0", (2, 5), payload)
    assert calls == [], "in-proc payload must be lazy (zero-copy at wait)"
    assert tr.wait(h) == "block"
    assert calls == [1]
    assert tr.counters() == dict(remote_fetches=1, retries=0, timeouts=0,
                                 dead_peers=0)
    assert tr.peer_counters()["w0"]["fetches"] == 1


def test_latency_jitter_delivery_window():
    from repro.runtime.transport import FaultProfile

    tr, clock = _fake(default=FaultProfile(latency=.2, jitter=.1),
                      timeout=1.0)
    t0 = clock()
    assert tr.fetch("w0", (0, 1), lambda: 42) == 42
    dt = clock() - t0
    assert .2 <= dt < .3, f"delivery at {dt}, expected latency+[0,jitter)"
    assert tr.counters()["retries"] == 0


def test_fake_rpc_snapshots_payload_at_issue():
    """serialize-at-send: the RPC payload is what the owner held at issue
    time, even if the block mutates before the response arrives."""
    from repro.runtime.transport import FaultProfile

    tr, _ = _fake(default=FaultProfile(latency=.1), timeout=1.0)
    cell = ["v1"]
    h = tr.fetch_async("w0", (0, 1), lambda: cell[0])
    cell[0] = "v2"
    assert tr.wait(h) == "v1"


def test_drop_all_exhausts_retry_budget_with_exact_backoff():
    """drop=1.0: attempt k waits out the timeout then backs off
    backoff * 2**k; after max_retries re-issues the final timeout declares
    the peer dead, fires on_dead once, and raises PeerDeadError."""
    from repro.runtime.transport import FaultProfile, PeerDeadError

    dead = []
    tr, clock = _fake({"w1": FaultProfile(drop=1.0)}, timeout=1.0,
                      max_retries=2, backoff=.5, on_dead=dead.append)
    h = tr.fetch_async("w1", (3, 7), lambda: "blk")
    with pytest.raises(PeerDeadError):
        tr.wait(h)
    # attempt 0: timeout 1.0, backoff .5 | attempt 1: 1.0, 1.0 | attempt 2:
    # final timeout 1.0 -> dead at 4.5 exactly
    assert clock() == pytest.approx((1.0 + .5) + (1.0 + 1.0) + 1.0)
    assert tr.counters() == dict(remote_fetches=1, retries=2, timeouts=3,
                                 dead_peers=1)
    assert dead == ["w1"], "on_dead must fire exactly once"
    # once dead, a new fetch fails FAST at issue (no timeout burned)
    t_before = clock()
    with pytest.raises(PeerDeadError):
        tr.fetch_async("w1", (3, 8), lambda: "blk")
    assert clock() == t_before


def test_latency_past_deadline_counts_as_timeout():
    """A response slower than the timeout is indistinguishable from a drop:
    the attempt times out and re-issues."""
    from repro.runtime.transport import FaultProfile, PeerDeadError

    tr, clock = _fake({"w0": FaultProfile(latency=5.0)}, timeout=1.0,
                      max_retries=1, backoff=.25)
    with pytest.raises(PeerDeadError):
        tr.fetch("w0", (0, 0), lambda: 1)
    assert clock() == pytest.approx((1.0 + .25) + 1.0)
    assert tr.counters()["timeouts"] == 2


def test_drop_some_eventually_delivers():
    """drop < 1: some seed has a dropped first attempt and a delivered
    retry — delivery time is exactly timeout + backoff + latency, and the
    payload survives the retry."""
    from repro.runtime.transport import FakeRpcTransport, FaultProfile, \
        manual_clock, PeerDeadError

    prof = FaultProfile(latency=.1, drop=.5)
    for seed in range(64):
        clock, sleep = manual_clock()
        tr = FakeRpcTransport(default=prof, timeout=1.0, max_retries=3,
                              backoff=.25, seed=seed, clock=clock,
                              sleep=sleep)
        try:
            v = tr.fetch("w0", (1, 2), lambda: "blk")
        except PeerDeadError:       # ~6% of seeds drop all 4 attempts
            continue
        assert v == "blk"
        if tr.counters()["retries"] == 1:
            assert clock() == pytest.approx(1.0 + .25 + .1)
            return
    pytest.fail("no seed in [0, 64) dropped exactly the first attempt")


def test_reorder_inverts_delivery_order_not_payloads():
    """With reorder probability, later-issued fetches can resolve earlier —
    responses overtake each other — but every handle still delivers ITS
    payload.  Deterministic: a fixed seed yields a fixed inversion set."""
    from repro.runtime.transport import FaultProfile

    tr, clock = _fake(default=FaultProfile(latency=.1, reorder=.5,
                                           reorder_delay=2.0),
                      timeout=5.0)
    keys = [(0, t) for t in range(12)]
    handles = [tr.fetch_async("w0", k, lambda k=k: k) for k in keys]
    ready = [tr._schedule(h.peer, h.key, h.issued_at).ready for h in handles]
    assert any(ready[i] > ready[j] for i in range(len(keys))
               for j in range(i + 1, len(keys))), \
        "reorder=.5 never inverted a pair"
    # wait in REVERSE issue order: payloads stay correct, clock is the max
    for h, k in zip(reversed(handles), reversed(keys)):
        assert tr.wait(h) == k
    assert clock() == pytest.approx(max(ready))


def test_schedule_is_deterministic_across_instances():
    """(seed, peer, key, attempt) fully determines the fault schedule: two
    transports with the same seed replay identical clock trajectories."""
    from repro.runtime.transport import FaultProfile

    times = []
    for _ in range(2):
        tr, clock = _fake(default=FaultProfile(latency=.2, jitter=.3,
                                               drop=.2),
                          timeout=1.0, max_retries=4)
        for key in [(0, 1), (1, 5), (3, 2)]:
            tr.fetch("w0", key, lambda: 0)
        times.append(clock())
    assert times[0] == times[1]


def test_mark_dead_fails_inflight_handles_fast():
    """External death (the fleet lost the worker): in-flight handles raise
    PeerDeadError at wait WITHOUT burning their timeout — mid-fetch loss."""
    from repro.runtime.transport import FaultProfile, PeerDeadError

    dead = []
    tr, clock = _fake(default=FaultProfile(latency=.5), timeout=1.0,
                      on_dead=dead.append)
    h = tr.fetch_async("w2", (4, 4), lambda: "blk")
    tr.mark_dead("w2")
    t0 = clock()
    with pytest.raises(PeerDeadError):
        tr.wait(h)
    assert clock() == t0, "dead-peer wait must not sleep"
    assert dead == [], "mark_dead is the external direction: no on_dead echo"
    assert tr.counters()["dead_peers"] == 1


def test_timeout_must_be_positive():
    from repro.runtime.transport import FakeRpcTransport

    with pytest.raises(ValueError):
        FakeRpcTransport(timeout=0.0)


# ---------------------------------------------------------------------------
# store-level: the sharded gallery through the fetch plane
# ---------------------------------------------------------------------------

def _sharded_store(transport=None, workers=("w0", "w1"), n_cams=8,
                   retention=100):
    import jax
    from repro.runtime.gallery import ShardedGalleryStore

    dev = jax.devices()[0]
    return ShardedGalleryStore(n_cams, retention, list(workers),
                               {w: dev for w in workers},
                               transport=transport)


def test_sharded_store_fetch_roundtrips_through_transport():
    """A transport-backed get returns the block bit-exactly, pays the
    injected latency, and ticks remote_fetches against the owner peer."""
    from repro.runtime.transport import FaultProfile

    tr, clock = _fake(default=FaultProfile(latency=.05), timeout=1.0)
    store = _sharded_store(transport=tr)
    blk = np.arange(12, dtype=np.float32).reshape(3, 4)
    cam = 2
    assert store.put(cam, 10, blk)
    t0 = clock()
    out = store.get(cam, 10)
    np.testing.assert_array_equal(out, blk)
    assert clock() - t0 == pytest.approx(.05)
    owner = store.owner_of(cam)
    assert tr.peer_counters()[owner]["fetches"] == 1
    c = store.counters()
    assert c["remote_fetches"] == 1 and c["hits"] == 1
    rep = store.per_worker_report()
    assert rep[owner]["remote_fetches"] == 1


def test_sharded_store_dead_owner_rehomes_and_fetch_retries():
    """End-to-end dead-peer path at the store level: the owner drops every
    attempt, on_dead re-homes its cameras, and the SAME blocking get
    retries against the new owner and succeeds — the caller never sees the
    death."""
    from repro.runtime.transport import FakeRpcTransport, FaultProfile, \
        manual_clock

    clock, sleep = manual_clock()
    holder = {}

    def on_dead(peer):
        survivors = [w for w in ("w0", "w1") if w != peer]
        holder["store"].rehome(peer, survivors)

    tr = FakeRpcTransport(clock=clock, sleep=sleep, timeout=.05,
                          max_retries=1, backoff=.01, on_dead=on_dead)
    store = holder["store"] = _sharded_store(transport=tr)
    victim_cam = 0
    victim = store.owner_of(victim_cam)
    tr.faults[victim] = FaultProfile(drop=1.0)
    blk = np.ones((2, 4), np.float32)
    assert store.put(victim_cam, 3, blk)
    out = store.get(victim_cam, 3)          # blocks, dies, rehomes, retries
    np.testing.assert_array_equal(out, blk)
    assert store.counters()["dead_peers"] == 1
    assert store.owner_of(victim_cam) != victim
    assert store.rehomed_blocks == 1


def test_sharded_store_dead_owner_without_rehome_surfaces():
    """No on_dead wiring (nobody re-homes): the failure surfaces instead of
    spinning."""
    from repro.runtime.transport import FakeRpcTransport, FaultProfile, \
        manual_clock, PeerDeadError

    clock, sleep = manual_clock()
    tr = FakeRpcTransport(clock=clock, sleep=sleep, timeout=.05,
                          max_retries=1, backoff=.01)
    store = _sharded_store(transport=tr)
    cam = 0
    tr.faults[store.owner_of(cam)] = FaultProfile(drop=1.0)
    assert store.put(cam, 3, np.ones((2, 4), np.float32))
    with pytest.raises(PeerDeadError):
        store.get(cam, 3)


# ---------------------------------------------------------------------------
# PrefetchPipeline: speculation accounting
# ---------------------------------------------------------------------------

def _frame_store(n_cams=4, retention=10):
    from repro.runtime.stream_store import FrameStore

    fs = FrameStore(n_cams, retention)
    return fs


def test_prefetch_hit_serves_block_and_accounts():
    from repro.runtime.transport import PrefetchPipeline

    fs = _frame_store()
    pipe = PrefetchPipeline(fs)
    blk = np.ones((2, 3), np.float32)
    fs.append(1, 5, blk)
    assert fs.put_emb(1, 5, blk)
    assert pipe.issue({(1, 5), (1, 99)}) == 1   # only the cached key issues
    assert pipe.in_flight == 1
    out = pipe.consume(1, 5)
    np.testing.assert_array_equal(out, blk)
    assert fs.gallery.prefetch_hits == 1
    assert fs.gallery.prefetch_wasted == 0
    assert pipe.in_flight == 0
    assert pipe.consume(1, 5) is None           # consumed: gone


def test_prefetch_eviction_between_issue_and_consume_is_wasted():
    """A block evicted after issue must NOT be served (the blocking path
    would miss it — serving it would change the trace): consume returns
    None and accounts the handle as wasted."""
    from repro.runtime.transport import PrefetchPipeline

    fs = _frame_store(retention=5)
    pipe = PrefetchPipeline(fs)
    blk = np.ones((2, 3), np.float32)
    fs.append(0, 0, blk)
    assert fs.put_emb(0, 0, blk)
    assert pipe.issue({(0, 0)}) == 1
    fs.append(0, 20, blk)                       # pushes (0,0) past retention
    assert pipe.consume(0, 0) is None
    assert fs.gallery.prefetch_wasted == 1
    assert fs.gallery.prefetch_hits == 0


def test_prefetch_sweep_drops_stale_handles():
    from repro.runtime.transport import PrefetchPipeline

    fs = _frame_store(retention=5)
    pipe = PrefetchPipeline(fs)
    blk = np.ones((1, 3), np.float32)
    fs.append(0, 0, blk)
    assert fs.put_emb(0, 0, blk)
    pipe.issue({(0, 0)})
    fs.append(0, 20, blk)
    assert pipe.sweep() == 1
    assert pipe.in_flight == 0
    assert fs.gallery.prefetch_wasted == 1


def test_prefetch_issues_only_replay_cursor_keys_in_mixed_cohorts():
    """Regression: with a MIXED cohort (replayers + live-frontier queries in
    the same tick), ``_issue_prefetch`` must filter the speculated keys down
    to replay cursors (``f_curr < t``) — a live-frontier block was ingested
    this tick and is not embedded yet, so issuing its key strands a handle
    that shows up as ``prefetch_wasted`` when a concurrent replayer embeds
    the frame.  The old guard only skipped the all-live cohort, so mixed
    cohorts leaked frontier keys.  Pin: every issued key sits strictly
    behind the wall clock, and waste stays exactly 0 when nothing is ever
    evicted (zero misspeculation)."""
    from conftest import make_serving_world
    from repro import api as rexcam
    from repro.core.policy import SearchPolicy

    world = make_serving_world(n_entities=80, horizon=300, seed=2,
                               n_queries=5)
    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60, replay_speed=2)
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, policy=policy,
                       geo_adj=world["net"].geo_adjacent, prefetch=True)
    issued = []
    real_issue = eng._prefetch.issue

    def spy(keys):
        issued.append((sorted(keys), eng.t))
        return real_issue(keys)

    eng._prefetch.issue = spy
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    mixed_ticks = 0
    for t in range(t0, vis.horizon + 500):
        if t < vis.horizon:
            frames = {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
            eng.ingest(frames)
        live = [q for q in eng.queries.values() if not q.done]
        mixed_ticks += (any(q.f_curr < eng.t for q in live)
                        and any(q.f_curr >= eng.t for q in live))
        eng.tick()
        if all(q.done for q in eng.queries.values()):
            break
    assert mixed_ticks > 0, "cohorts never mixed — the scenario is inert"
    assert issued, "prefetch never issued a key"
    for keys, t in issued:
        assert all(f < t for _c, f in keys), \
            f"prefetch issued live-frontier keys {keys} at t={t}"
    rep = eng.gallery_report()
    assert rep["prefetch_wasted"] == 0, rep
    assert rep["prefetch_hits"] > 0, \
        "prefetch never served a block — the pipeline is inert here"


def test_counters_have_transport_era_keys_everywhere():
    """Every GalleryStore reports the transport-era keys (zeros without a
    transport) so reports are shape-stable across backends."""
    from repro.runtime.gallery import LocalGalleryStore

    for c in (LocalGalleryStore(4, 10).counters(),
              _sharded_store().counters()):
        for k in ("remote_fetches", "prefetch_hits", "prefetch_wasted",
                  "retries", "timeouts"):
            assert k in c and c[k] == 0, (k, c)


def test_api_serve_transport_validation():
    """transport= demands the sharded fleet gallery; the string shorthand
    resolves; junk strings fail loudly."""
    from conftest import make_serving_world
    from repro import api as rexcam
    from repro.runtime.transport import InProcTransport

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    with pytest.raises(ValueError):
        rexcam.serve(world["model"], embed_fn=lambda x: x,
                     transport=InProcTransport())          # no fleet
    with pytest.raises(ValueError):
        rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1,
                     transport="quic")                     # unknown name
    with pytest.raises(ValueError):
        rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1,
                     gallery="local", transport="inproc")  # no owners
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1,
                       transport="inproc", prefetch=True)
    assert eng.gallery.transport is not None
    assert eng.gallery.transport.kind == "inproc"


# ---------------------------------------------------------------------------
# the fleet differential matrix on 8 fake CPU devices
# ---------------------------------------------------------------------------

def test_fleet_transport_trace_identical_across_shard_counts():
    """Fake-RPC (latency+jitter) with prefetch, and the named in-proc
    transport, each bit-identical to the single engine for shards
    {1, 2, 4, 8}."""
    _fleet_case("fleet_case_transport_shard_counts")


def test_fleet_transport_fault_matrix():
    """drop+retry, reorder, and blocking heavy latency: trace-identical,
    with the retry counters proving the faults actually fired."""
    _fleet_case("fleet_case_transport_faults")


def test_fleet_transport_timeout_drives_rehome():
    """An all-drop peer dies mid-round; the gallery re-homes immediately,
    the blocked fetch retries against the new owner, and the fleet scales
    down at the tick boundary — trace identical throughout."""
    _fleet_case("fleet_case_transport_timeout_rehome")


def test_fleet_transport_midfetch_worker_loss():
    """Worker loss with prefetch handles in flight: handles to the lost
    peer fail fast and the rounds fall back to blocking fetches from the
    re-homed owner — trace identical, waste exactly accounted."""
    _fleet_case("fleet_case_transport_midfetch_loss")
