"""The 130-camera soak plane, scaled down to test size.

Two layers:

  * cheap in-process unit tests of ``clustered_city_network`` — the large
    synthetic topology generator the soak scenario is built on must be
    bit-reproducible per seed, row-stochastic, and geometrically sane at
    any camera count;
  * the soak DIFFERENTIAL (``conftest.fleet_case_soak`` via the shared
    ``_fleet_case`` runner): query churn + worker loss + a targeted
    recalibration swap in ONE run, trace-identical across shard counts
    {1, 2, 4, 8} on 8 fake CPU devices.
"""
import numpy as np

from test_sharded_engine import _fleet_case


def _city(**kw):
    from repro.core import clustered_city_network
    return clustered_city_network(**kw)


def test_city_network_bit_reproducible():
    a = _city(n_cams=130, seed=17)
    b = _city(n_cams=130, seed=17)
    for f in ("trans", "travel_mean", "travel_std", "entry"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    c = _city(n_cams=130, seed=18)
    assert not np.array_equal(a.trans, c.trans), "seed must matter"


def test_city_network_row_stochastic_and_geo():
    for C in (32, 130):
        net = _city(n_cams=C, seed=5)
        assert net.n_cams == C
        # each row's camera mass + exit mass must be a distribution
        np.testing.assert_allclose(net.trans[:, :C].sum(1), 0.85, atol=1e-6)
        assert (net.trans >= 0).all()
        # geo adjacency: symmetric, no self-loops, connected enough that
        # every camera has at least one neighbor (leaf ring + hub links)
        geo = np.asarray(net.geo_adjacent)
        assert (geo == geo.T).all() and not geo.diagonal().any()
        assert geo.any(axis=1).all()
        # entry distribution sums to one with hub emphasis
        np.testing.assert_allclose(net.entry.sum(), 1.0, atol=1e-6)
        assert net.entry.max() > 1.0 / C
        # clustered travel times: intra-cluster hops are faster than the
        # corridor hops (means drawn from disjoint [8,20) vs [30,70) bands)
        linked = net.trans[:, :C] > 0
        assert net.travel_mean[linked].min() >= 8.0
        assert net.travel_mean[linked].max() < 70.0


def test_city_network_simulates():
    from repro.core import simulate_network
    net = _city(n_cams=32, seed=7)
    vis = simulate_network(net, 60, 240, seed=1)
    assert len(vis.ent) > 0
    assert int(vis.cam.max()) < 32


def test_soak_differential_trace_identical():
    """Churn + loss + targeted recal swap in one run, bit-identical across
    shard counts — THE scaled-down soak gate (see conftest.fleet_case_soak
    for the full assertion list)."""
    _fleet_case("fleet_case_soak", timeout=1500)
