import collections
import dataclasses
import functools
import os
import sys

# NOTE: no xla_force_host_platform_device_count here — smoke tests and benches
# must see 1 device.  Sharding tests spawn subprocesses that set the flag.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


# ---------------------------------------------------------------------------
# Fleet differential harness (tests/test_sharded_engine.py + its subprocess
# re-entry).  Everything below is import-safe — jax/repro imports stay inside
# the functions so collecting this conftest never initializes a jax backend.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def make_serving_world(n_entities=100, horizon=360, seed=0, n_queries=4):
    """Small duke-like world for engine differential tests (process-cached).

    Returns plain arrays (model, visits, gallery, features, query vids) —
    the same scenario shape the benchmarks use, sized for tick-by-tick
    double (single + fleet) runs."""
    from repro.core import (build_gallery, build_model, duke_like_network,
                            simulate_network)
    from repro.core.features import FeatureParams, make_features
    from repro.core.tracker import make_queries

    net = duke_like_network()
    vis = simulate_network(net, n_entities, horizon, seed=seed)
    gal, _ = build_gallery(vis, 16)
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                        time_limit=int(horizon * 0.7))
    feats, _ = make_features(vis, n_entities, FeatureParams(seed=seed))
    q_vids, gt_vids = make_queries(vis, n_queries, seed=seed + 1)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids)


def make_drifted_world(n_entities=80, t_shift=150, horizon=420, seed=0,
                       n_queries=6):
    """Serving world whose live stream SHIFTS topology mid-run (a camera
    permutation at ``t_shift``) while the profile model stays frozen on the
    pre-shift world — the §6 drift injection the recalibration differential
    runs on.  Queries are drawn from the post-shift traffic."""
    from repro.core import (build_gallery, build_model, concat_visits,
                            duke_like_network, permute_network,
                            simulate_network)
    from repro.core.features import FeatureParams, make_features
    from repro.core.tracker import make_queries

    net = duke_like_network()
    shifted = permute_network(net, np.roll(np.arange(net.n_cams), 3))
    hist = simulate_network(net, 400, 900, seed=seed + 50)
    model = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, net.n_cams)
    vis_a = simulate_network(net, n_entities // 2, t_shift, seed=seed + 51)
    vis_b = simulate_network(shifted, n_entities, horizon - t_shift,
                             seed=seed + 52)
    vis = concat_visits(vis_a, vis_b, t_shift)
    gal, _ = build_gallery(vis, 16)
    feats, _ = make_features(vis, int(vis.ent.max()) + 1,
                             FeatureParams(seed=seed + 52))
    q_b, gt_b = make_queries(vis_b, n_queries, seed=seed + 53)
    q_vids = q_b + len(vis_a)
    gt_vids = np.where(gt_b >= 0, gt_b + len(vis_a), gt_b)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, t_shift=t_shift)


def make_soak_world(n_cams=32, n_entities=90, t_shift=160, horizon=480,
                    seed=0, n_queries=8, anchor_hi=140):
    """Scaled-down 130-camera soak world: the clustered city topology
    (``clustered_city_network``) with a LOCALIZED mid-run drift — two hub
    rows' arterial mass is rerouted onto their weakest leaf edges (edges
    that sit below ``s_thresh`` in the profiled model but above the relaxed
    replay threshold), so phase 1 misses the shifted hops while phase-2
    rescues keep the chains alive AND pile the §6 drift signal onto exactly
    those source rows.  Most rows stay truthful, so a row-targeted
    re-profile is the right response.  The profile trains on dense history
    (travel-time support bounds chain survival at this scale) and queries
    anchor early in the post-shift traffic so every chain has runway across
    the drift."""
    from repro.core import (build_gallery, build_model,
                            clustered_city_network, concat_visits,
                            simulate_network)
    from repro.core.features import FeatureParams, make_features
    from repro.core.tracker import make_queries

    # 3 big neighborhoods: the hub fanout must be wide enough that the
    # weakest leaf edges straddle s_thresh (the same regime the 130-camera
    # city hits naturally) — that is what makes the rerouted hops phase-2
    # rescues rather than silent phase-1 admits
    net = clustered_city_network(n_cams=n_cams, n_clusters=3, seed=seed + 40)
    hubs = np.flatnonzero(net.entry > 1.0 / n_cams)
    drift_rows = hubs[:2]
    T = net.trans.copy()
    for h in drift_rows:
        row = T[h, :n_cams]
        dests = np.flatnonzero(row)
        order = np.argsort(row[dests])
        boost, take = dests[order[:3]], dests[order[-3:]]
        moved = 0.7 * row[take].sum()
        row[take] *= 0.3
        row[boost] += moved / len(boost)
    shifted = dataclasses.replace(net, trans=T)
    hist = simulate_network(net, n_entities * 16, 2000, seed=seed + 50)
    model = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, n_cams)
    vis_a = simulate_network(net, n_entities // 2, t_shift, seed=seed + 51)
    vis_b = simulate_network(shifted, n_entities, horizon - t_shift,
                             seed=seed + 52)
    vis = concat_visits(vis_a, vis_b, t_shift)
    gal, _ = build_gallery(vis, 16)
    feats, _ = make_features(vis, int(vis.ent.max()) + 1,
                             FeatureParams(seed=seed + 52))
    q_b, gt_b = make_queries(vis_b, 8 * n_queries, seed=seed + 53)
    keep = np.flatnonzero(vis_b.t_out[q_b] <= anchor_hi)[:n_queries]
    q_b, gt_b = q_b[keep], gt_b[keep]
    q_vids = q_b + len(vis_a)
    gt_vids = np.where(gt_b >= 0, gt_b + len(vis_a), gt_b)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, t_shift=t_shift,
                drift_rows=drift_rows)


def drive_serving_trace(world, policy, *, shards=None, lose_at=None,
                        lose_worker=0, extra_ticks=500, gallery="auto",
                        topk=1, embed_fn=None, recalibrate=None,
                        transport=None, prefetch=False, consolidate=True,
                        tile_grid=0, topk_rerank=False, model=None,
                        churn_wave=None):
    """Run one engine (single-process when ``shards`` is None, else the
    sharded fleet) over the world's live stream and return (engine, trace,
    summary).  ``lose_at`` kills one worker that many ticks into the run —
    the fleet rebalances; the single engine ignores it.  ``gallery`` picks
    the embedding plane ("auto": local for one engine, fleet-shared sharded
    store for the fleet).  ``recalibrate`` (a RecalibrationPolicy) attaches
    the §6 drift loop, re-profiling from the world's ground-truth visits.
    ``transport`` routes the fleet's gallery fetches through a
    ``runtime.transport.Transport`` — pass a zero-arg FACTORY (callable or
    class) so every drive gets fresh transport state; ``prefetch`` turns on
    the double-buffered speculative fetch pipeline.  ``tile_grid=T > 0``
    serves through the sub-frame spatial admission plane (per-detection
    tile labels from the world's ground-truth positions ride along with
    every ingest); ``model`` overrides the world's profile (e.g. a
    tile-carrying re-profile of the same visits).  ``churn_wave`` splits the
    submits: the first half goes in at t0 and the rest that many steps in
    (the late wave replays to catch up) — query churn for the soak cases."""
    from repro import api as rexcam

    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    q_vids = world["q_vids"]
    if callable(transport):
        transport = transport()
    vis_tiles = None
    if tile_grid > 0:
        from repro.core.simulate import tile_index
        vis_tiles = tile_index(vis.tile_xy, tile_grid)
    eng = rexcam.serve(world["model"] if model is None else model,
                       embed_fn=embed_fn if embed_fn is not None
                       else lambda x: x,
                       policy=policy,
                       geo_adj=world["net"].geo_adjacent, shards=shards,
                       gallery=gallery, topk=topk, recalibrate=recalibrate,
                       transport=transport, prefetch=prefetch,
                       consolidate=consolidate, tile_grid=tile_grid,
                       topk_rerank=topk_rerank,
                       visit_source=rexcam.visits_window_source(vis)
                       if recalibrate is not None else None)
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    first = len(q_vids) if churn_wave is None else max(1, len(q_vids) // 2)
    for i in range(first):
        q = q_vids[i]
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    trace = []
    for step, t in enumerate(range(t0, vis.horizon + extra_ticks)):
        if churn_wave is not None and step == churn_wave:
            for j in range(first, len(q_vids)):
                q = q_vids[j]
                eng.submit_query(j, feats[q], int(vis.cam[q]),
                                 int(vis.t_out[q]))
        if lose_at is not None and step == lose_at and shards is not None:
            eng.lose_worker(lose_worker)
        if t < vis.horizon:
            frames, tiles = {}, {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
                    if vis_tiles is not None:
                        tiles[c] = vis_tiles[vids]
            if tile_grid > 0:
                eng.ingest(frames, tiles)
            else:
                eng.ingest(frames)
        eng.tick(record_trace=trace)
        if all(q.done for q in eng.queries.values()) and \
                (churn_wave is None or step >= churn_wave):
            break
    summary = dict(
        admitted_steps=eng.admitted_steps, unique_frames=eng.unique_frames,
        content_steps=eng.content_steps, replay_steps=eng.replay_steps,
        rescue_pairs=eng.rescue_pairs.copy(),
        model_epoch=eng.model_epoch, model_swaps=list(eng.model_swaps),
        per_query=[(q.matches, q.rescued, q.done, q.phase, q.f_curr)
                   for q in eng.queries.values()])
    return eng, trace, summary


def trace_key(trace):
    """Canonical per-round tuple stream: admissions (mask), the match
    decision, tie-break (gallery row index), raw kernel score, the
    top-k (value, cam, frame) candidate bands and the model epoch the
    round ran under (recalibration swap boundaries)."""
    return [(r["qid"], r["f_curr"], r["phase"], r["epoch"],
             tuple(bool(x) for x in r["mask"]), bool(r["matched"]),
             int(r["match_cam"]), float(r["match_val"]), int(r["match_idx"]),
             tuple(r["topk"]))
            for r in trace]


def assert_fleet_trace_identical(world, policy, shards, *, lose_at=None,
                                 lose_worker=0, single=None, gallery="auto",
                                 recalibrate=None, transport=None,
                                 prefetch=False, consolidate=True,
                                 single_consolidate=True, churn_wave=None):
    """THE differential assertion: the sharded fleet's rounds are
    bit-identical to the single-process engine's — admissions, match
    indices/values (tie-breaks included), rescue attribution, model-epoch
    boundaries (recalibration swaps land on the same round), and both
    cost conventions.  Returns (fleet engine, single (trace, summary)) so
    callers can layer fleet-specific asserts on top; pass ``single`` (a
    prior return) to reuse the reference run across shard counts.
    ``transport``/``prefetch`` apply to the FLEET run only (the reference
    single engine has no remote owners) — transport must never change what
    is ranked, only when it arrives, so the assertion is unchanged."""
    from repro.runtime.gallery import ShardedGalleryStore

    if single is None:
        _, ref_trace, ref_sum = drive_serving_trace(
            world, policy, recalibrate=recalibrate,
            consolidate=single_consolidate, churn_wave=churn_wave)
        single = (ref_trace, ref_sum)
    ref_trace, ref_sum = single
    eng, fl_trace, fl_sum = drive_serving_trace(
        world, policy, shards=shards, lose_at=lose_at,
        lose_worker=lose_worker, gallery=gallery, recalibrate=recalibrate,
        transport=transport, prefetch=prefetch, consolidate=consolidate,
        churn_wave=churn_wave)
    assert trace_key(fl_trace) == trace_key(ref_trace), \
        f"fleet (shards={shards}) trace diverged from the single engine"
    assert fl_sum["admitted_steps"] == ref_sum["admitted_steps"]
    assert fl_sum["unique_frames"] == ref_sum["unique_frames"]
    assert fl_sum["content_steps"] == ref_sum["content_steps"]
    assert fl_sum["replay_steps"] == ref_sum["replay_steps"]
    np.testing.assert_array_equal(fl_sum["rescue_pairs"],
                                  ref_sum["rescue_pairs"])
    assert fl_sum["model_epoch"] == ref_sum["model_epoch"]
    assert fl_sum["model_swaps"] == ref_sum["model_swaps"], \
        "recalibration swaps did not land on the same ticks fleet-wide"
    assert fl_sum["per_query"] == ref_sum["per_query"]
    # per-shard accounting must tile the fleet totals (admitted) / at least
    # cover them (unique frames are shard-local dedup, so >= the global);
    # owner attribution tiles the fleet-GLOBAL dedup set exactly
    rep = eng.shard_report()
    assert sum(r["admitted_steps"] for r in rep) == eng.admitted_steps
    assert sum(r["unique_frames"] for r in rep) >= eng.unique_frames
    if isinstance(eng.gallery, ShardedGalleryStore):
        assert sum(r["owned_frames"] for r in rep) == eng.unique_frames
    return eng, single


def _require_devices(n):
    import jax
    assert len(jax.devices()) >= n, (
        f"need {n} devices, have {len(jax.devices())} — set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu")


def fleet_case_shard_counts(shard_counts=(1, 2, 4, 8), n_queries=5, seed=0):
    """Differential case: every shard count in ``shard_counts`` is
    trace-identical to the single engine — with a query count NOT divisible
    by any shard count > 1 (5 % {2,4,8} != 0, so shard blocks carry ragged
    padding), then once more with an exactly-divisible count."""
    from repro.core.policy import SearchPolicy

    _require_devices(max(shard_counts))
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    single = None
    for shards in shard_counts:
        eng, single = assert_fleet_trace_identical(world, policy, shards,
                                                   single=single)
        # submit-time placement is least-loaded: never more than one query
        # of imbalance between live workers (counted over the placement map,
        # which survives query completion — shard_report loads go to 0)
        counts = collections.Counter(eng._placement.values())
        loads = [counts.get(r["worker"], 0)
                 for r in eng.shard_report() if r["alive"]]
        assert max(loads) - min(loads) <= 1, loads
    divisible = make_serving_world(seed=seed + 10, n_queries=4)
    assert_fleet_trace_identical(world=divisible, policy=policy, shards=4)


def fleet_case_worker_loss(shards=4, lose_worker=1, lose_at=50,
                           n_queries=7, seed=1):
    """Differential case: killing a worker mid-run shrinks the data axis to
    ``shards - 1`` and re-scatters its queries — and the trace stays
    bit-identical to the single engine (placement never changes results)."""
    from repro.core.policy import SearchPolicy

    _require_devices(shards)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    eng, _ = assert_fleet_trace_identical(world, policy, shards,
                                          lose_at=lose_at,
                                          lose_worker=lose_worker)
    assert eng.n_shards == shards - 1
    assert eng.rebalances == 1
    rep = {r["worker"]: r for r in eng.shard_report()}
    lost = f"w{lose_worker}"
    assert not rep[lost]["alive"]
    assert rep[lost]["admitted_steps"] > 0, \
        "the lost worker never served a round — lose_at fired too early"
    live = {w for w, r in rep.items() if r["alive"]}
    assert set(eng._placement.values()) <= live, "orphans not re-scattered"
    # the gallery plane re-homed alongside the query re-scatter: the lost
    # worker owns no cameras anymore (fleet default gallery is sharded)
    assert eng.gallery.kind == "sharded"
    assert lost not in set(eng.gallery._owner.values())


def fleet_case_consolidation(shard_counts=(1, 2, 4, 8), n_queries=5, seed=3,
                             lose_at=50, lose_worker=1):
    """The tentpole differential: the consolidated segment-ID path (one
    ``reid_topk_segments`` call over the fleet-global RoundPlan) is
    trace-identical to the UNCONSOLIDATED per-frame reference engine — the
    reference single run here uses ``consolidate=False`` so the assertion
    crosses both the fleet/single boundary AND the segment/frame-tag kernel
    boundary in one differential.  Covers a query count not divisible by any
    shard count > 1 (ragged shard padding) plus a mid-run worker-loss leg."""
    from repro.core.policy import SearchPolicy

    _require_devices(max(shard_counts))
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    single = None
    for shards in shard_counts:
        _, single = assert_fleet_trace_identical(
            world, policy, shards, single=single,
            consolidate=True, single_consolidate=False)
    # consolidated single engine against the same unconsolidated reference
    _, c_trace, c_sum = drive_serving_trace(world, policy, consolidate=True)
    ref_trace, ref_sum = single
    assert trace_key(c_trace) == trace_key(ref_trace), \
        "consolidated single engine diverged from the per-frame path"
    assert c_sum["per_query"] == ref_sum["per_query"]
    assert c_sum["admitted_steps"] == ref_sum["admitted_steps"]
    assert c_sum["unique_frames"] == ref_sum["unique_frames"]
    assert c_sum["content_steps"] == ref_sum["content_steps"]
    assert c_sum["replay_steps"] == ref_sum["replay_steps"]
    np.testing.assert_array_equal(c_sum["rescue_pairs"],
                                  ref_sum["rescue_pairs"])
    # worker loss mid-run with the consolidated fleet path
    world2 = make_serving_world(seed=seed + 1, n_queries=7)
    eng, _ = assert_fleet_trace_identical(
        world2, policy, max(shard_counts) // 2, lose_at=lose_at,
        lose_worker=lose_worker, consolidate=True, single_consolidate=False)
    assert eng.rebalances == 1


def fleet_case_tiles(shard_counts=(1, 2, 4, 8), T=4, n_queries=5, seed=3,
                     lose_at=50, lose_worker=1):
    """The sub-frame spatial admission differential: serving with
    ``tile_grid=T`` over a model WITHOUT tile data (the engine synthesizes
    the all-tiles-admitted tensor) is trace-identical to camera-granular
    serving — admissions, match indices/values (tie-breaks included),
    rescue attribution, both cost conventions — for the single engine AND
    every shard count, plus a mid-run worker-loss leg.  All-admitted tile
    accounting must tile exactly: T*T tiles per admitted camera-step and
    per unique frame (the camera-granular pixel-load ceiling the learned
    masks are measured against)."""
    from repro.core.policy import SearchPolicy

    _require_devices(max(shard_counts))
    TT = T * T
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    _, ref_trace, ref_sum = drive_serving_trace(world, policy)
    for shards in (None,) + tuple(shard_counts):
        eng, tr, sm = drive_serving_trace(world, policy, shards=shards,
                                          tile_grid=T)
        assert trace_key(tr) == trace_key(ref_trace), \
            f"tile path (shards={shards}) diverged from the camera path"
        for f in ("admitted_steps", "unique_frames", "content_steps",
                  "replay_steps", "model_epoch", "per_query"):
            assert sm[f] == ref_sum[f], f"tile path changed {f}"
        np.testing.assert_array_equal(sm["rescue_pairs"],
                                      ref_sum["rescue_pairs"])
        assert eng.admitted_tiles == TT * eng.admitted_steps, \
            "all-admitted tile accounting does not tile admitted_steps"
        assert eng.unique_tiles == TT * eng.unique_frames, \
            "all-admitted tile dedup does not tile unique_frames"
    # worker loss mid-run on the tile path
    world2 = make_serving_world(seed=seed + 1, n_queries=7)
    _, r2_trace, r2_sum = drive_serving_trace(world2, policy)
    eng, tr, sm = drive_serving_trace(
        world2, policy, shards=max(shard_counts) // 2, lose_at=lose_at,
        lose_worker=lose_worker, tile_grid=T)
    assert trace_key(tr) == trace_key(r2_trace), \
        "tile fleet diverged from the camera path across a worker loss"
    assert sm["per_query"] == r2_sum["per_query"]
    assert eng.rebalances == 1
    assert eng.admitted_tiles == TT * eng.admitted_steps


def fleet_case_plan_conservation(shard_counts=(1, 2, 4, 8), n_queries=5,
                                 seed=4):
    """Satellite regression: every RoundPlan conserves admission mass.  Per
    round, ``sum(want_count.values())`` (how many (query, camera) steps
    each unique (cam, frame) key serves) must equal ``plan.admitted`` (the
    admission mask's popcount over live rows) and the per-query camera
    lists; ``work`` must be exactly the sorted key set; and the per-plan
    admitted sum over a whole run must reproduce the engine's
    ``admitted_steps`` total — across consolidate on/off and every shard
    count, because dedup/consolidation is an execution-plan change that may
    never create or lose an admission step."""
    from repro.core.policy import SearchPolicy
    from repro.runtime.engine import ServingEngine

    _require_devices(max(shard_counts))
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    orig = ServingEngine._plan_round
    total = [0]

    def checked(self, qs):
        plan = orig(self, qs)
        per_key = sum(plan.want_count.values())
        assert per_key == plan.admitted == int(plan.mask[plan.slots].sum()), \
            f"plan lost admission mass: {per_key} keyed vs {plan.admitted}"
        assert plan.work == sorted(plan.want_count), \
            "work queue is not exactly the sorted want_count key set"
        assert plan.admitted == sum(len(c) for c in plan.cams_by_q), \
            "per-query camera lists do not tile the admitted count"
        total[0] += plan.admitted
        return plan

    ServingEngine._plan_round = checked
    try:
        for consolidate in (True, False):
            for shards in (None,) + tuple(shard_counts):
                total[0] = 0
                eng, _, _ = drive_serving_trace(world, policy, shards=shards,
                                                consolidate=consolidate)
                assert total[0] == eng.admitted_steps, \
                    (f"consolidate={consolidate} shards={shards}: per-plan "
                     f"admitted {total[0]} != engine admitted_steps "
                     f"{eng.admitted_steps}")
    finally:
        ServingEngine._plan_round = orig


def fleet_case_recalibration(shard_counts=(2, 4, 8), n_queries=8, seed=0):
    """Differential case for the §6 recalibration loop: on a mid-run
    topology shift, the controller re-profiles and hot-swaps M — and the
    fleet stays bit-identical to the single engine INCLUDING the model-epoch
    boundaries in every trace record (the swap lands on the same round on
    every shard).  The single run must actually swap (epoch > 0), both
    pre- and post-swap rounds must appear in the trace, and the hot-swap
    must never drop an in-flight query."""
    from repro.core.policy import SearchPolicy
    from repro.runtime.recal import RecalibrationPolicy

    _require_devices(max(shard_counts))
    # exit_t must outlast duke travel times (~44 +- 10 plus dwell) or phase-2
    # replay expires before the entity reappears and no rescues ever accrue
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=120)
    # test-world trigger: tiny fleet -> few rescues, so trip early and often
    # enough that at least one swap lands mid-trace
    recal = RecalibrationPolicy(drift_threshold=.02, min_rescues=2,
                                cooldown=60, poll_every=10, window=200)
    world = make_drifted_world(seed=seed, n_queries=n_queries, horizon=500)
    _, ref_trace, ref_sum = drive_serving_trace(world, policy,
                                                recalibrate=recal)
    single = (ref_trace, ref_sum)
    assert ref_sum["model_epoch"] >= 1, \
        "drifted world never tripped the recalibration trigger"
    epochs = {r["epoch"] for r in ref_trace}
    assert len(epochs) >= 2, "no pre/post-swap rounds both present in trace"
    live_at_swap = ref_sum["model_swaps"][0][0]
    n_alive = sum(1 for (_m, _r, done, _p, f) in ref_sum["per_query"]
                  if f > live_at_swap)
    assert n_alive > 0, "swap landed after every query finished"
    for shards in shard_counts:
        eng, single = assert_fleet_trace_identical(
            world, policy, shards, single=single, recalibrate=recal)
        assert eng.model_epoch == ref_sum["model_epoch"]
        assert int(eng.model.epoch) == eng.model_epoch


def fleet_case_soak(shard_counts=(1, 2, 4, 8), n_queries=8, seed=3,
                    churn_wave=40, lose_at=90, lose_worker=1):
    """The scaled-down soak differential: query churn (a late submit wave),
    worker loss, and a TARGETED recalibration swap all in ONE run — and
    the fleet trace stays bit-identical to the single engine at every shard
    count.  The single reference is reused across legs; loss only applies
    on the multi-shard legs (a 1-shard fleet has no worker to spare).
    On top of the differential, asserts the soak actually soaked: a swap
    landed mid-trace, the late wave replayed, the lossy legs rebalanced
    exactly once, and the targeted controller re-profiled a strict subset
    of the model's rows."""
    from repro.core.policy import SearchPolicy
    from repro.runtime.recal import RecalibrationPolicy

    _require_devices(max(shard_counts))
    # exit_t must outlast the city network's corridor travel times (30-70s)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=120)
    # the dense prior keeps normalized per-pair scores small — gate the trip
    # on the sustained rescue count, and keep the re-profiling window wide
    # enough that merged rows carry real travel-time support
    recal = RecalibrationPolicy(drift_threshold=.005, min_rescues=2,
                                cooldown=80, poll_every=10, window=250,
                                targeted=True, row_threshold=.02)
    world = make_soak_world(seed=seed, n_queries=n_queries)
    C = world["net"].n_cams
    single = None
    eng = None
    for shards in shard_counts:
        loss = lose_at if shards >= 2 else None
        eng, single = assert_fleet_trace_identical(
            world, policy, shards, single=single, recalibrate=recal,
            churn_wave=churn_wave, lose_at=loss, lose_worker=lose_worker)
        if loss is not None:
            assert eng.rebalances == 1
    ref_trace, ref_sum = single
    assert ref_sum["model_epoch"] >= 1, \
        "soak world never tripped the recalibration trigger"
    assert len({r["epoch"] for r in ref_trace}) >= 2, \
        "no pre/post-swap rounds both present in trace"
    assert ref_sum["replay_steps"] > 0, "late wave never replayed"
    # targeted accounting: every swap re-profiled a strict subset of rows
    ctl = eng.recal
    assert ctl.targeted_swaps >= 1 and ctl.full_rebuilds == 0
    assert ctl.rows_reprofiled < C * ctl.targeted_swaps, \
        f"targeted recal touched {ctl.rows_reprofiled} rows over " \
        f"{ctl.targeted_swaps} swaps — no better than a full rebuild (C={C})"
    for ev in ctl.events:
        assert ev["mode"] == "targeted" and 0 < ev["rows"] < C


def _drive_counting(world, policy, *, shards=None, gallery="auto",
                    extra_ticks=500):
    """Like ``drive_serving_trace`` but every ingested (cam, t) frame batch
    carries a tag column and ``embed_fn`` counts embed EVENTS per tag —
    the instrument for "no (cam, frame) pair is ever embedded twice" and
    "fleet-global embed calls == the single engine's".  Returns
    (engine, trace, Counter{tag: embed events})."""
    from repro import api as rexcam

    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    q_vids = world["q_vids"]
    H = vis.horizon + 1
    embedded = collections.Counter()

    def embed_fn(x):
        for tag in sorted(set(x[:, -1].tolist())):
            embedded[int(tag)] += 1
        return x[:, :-1]

    eng = rexcam.serve(world["model"], embed_fn=embed_fn, policy=policy,
                       geo_adj=world["net"].geo_adjacent, shards=shards,
                       gallery=gallery)
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    trace = []
    for t in range(t0, vis.horizon + extra_ticks):
        if t < vis.horizon:
            frames = {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    crops = feats[vids]
                    tag = np.full((len(crops), 1), c * H + t, np.float32)
                    frames[c] = np.concatenate([crops, tag], 1)
            eng.ingest(frames)
        eng.tick(record_trace=trace)
        if all(q.done for q in eng.queries.values()):
            break
    return eng, trace, embedded


def fleet_case_gallery_modes(shards=4, n_queries=5, seed=0):
    """The gallery-plane differential (the PR-4 tentpole contract): with the
    fleet-shared ``ShardedGalleryStore`` AND with the replicated-baseline
    ``LocalGalleryStore``, the fleet is trace-identical to the single
    engine, no (cam, frame) pair ever reaches ``embed_fn`` twice fleet-wide,
    and fleet-global embed calls EQUAL the single engine's (one embedding
    plane — no per-shard re-embedding of the deduplicated demand)."""
    from repro.core.policy import SearchPolicy

    _require_devices(shards)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    single, s_trace, s_counter = _drive_counting(world, policy)
    assert single.frames_processed > 0
    assert s_counter and max(s_counter.values()) == 1, \
        "single engine re-embedded a (cam, frame) pair"
    for mode in ("sharded", "local"):
        eng, f_trace, f_counter = _drive_counting(world, policy,
                                                  shards=shards, gallery=mode)
        assert eng.gallery.kind == mode
        assert trace_key(f_trace) == trace_key(s_trace), \
            f"gallery={mode} fleet trace diverged from the single engine"
        assert max(f_counter.values()) == 1, \
            f"gallery={mode} fleet re-embedded a (cam, frame) pair"
        assert f_counter == s_counter, \
            f"gallery={mode} fleet embed calls differ from the single engine"
        assert eng.frames_processed == single.frames_processed
        assert eng.unique_frames == single.unique_frames
        assert eng.cache_hits == single.cache_hits
        rep = eng.shard_report()
        if mode == "sharded":
            # owner attribution tiles the fleet-global dedup set exactly,
            # and the resident blocks live where their camera's owner is
            assert sum(r["owned_frames"] for r in rep) == eng.unique_frames
            per_w = eng.gallery.per_worker_report()
            assert sum(v["blocks"] for v in per_w.values()) == \
                eng.store.cached_embeddings()
            assert sum(v["cameras"] for v in per_w.values()) == eng.C
        else:
            assert all(r["owned_frames"] == 0 for r in rep)


def fleet_case_gallery_rehome(shards=4, lose_worker=1, warmup=60,
                              n_queries=6, seed=1):
    """Worker loss re-homes the gallery plane: the lost worker's cameras
    (and their device-resident blocks) migrate to survivors chosen by the
    camera hash, block VALUES survive the move bit-exactly, and surviving
    owners keep their cameras (only the lost shard moves)."""
    from repro import api as rexcam

    _require_devices(shards)
    from repro.core.policy import SearchPolicy

    world = make_serving_world(seed=seed, n_queries=n_queries)
    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, policy=policy,
                       geo_adj=world["net"].geo_adjacent, shards=shards)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    for t in range(t0, t0 + warmup):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        eng.tick()

    store = eng.gallery
    lost = f"w{lose_worker}"
    pre_owner = dict(store._owner)
    owned_keys = [k for k in store._blocks if store.owner_of(k[0]) == lost]
    assert owned_keys, \
        f"warmup never cached a block owned by {lost} — warmup too short?"
    pre_vals = {k: store._fetch(*k).copy() for k in owned_keys}
    rehomed_before = store.rehomed_blocks

    eng.lose_worker(lose_worker)

    assert store.rehomed_blocks - rehomed_before == len(owned_keys)
    assert lost not in set(store._owner.values())
    for cam, w in pre_owner.items():
        if w != lost:       # survivors keep their cameras
            assert store._owner[cam] == w
    for k in owned_keys:
        new_owner = store.owner_of(k[0])
        assert new_owner in eng._workers
        arr, _n = store._blocks[k]
        assert {d for d in arr.devices()} == \
            {eng._device_of[new_owner]}, f"block {k} not on its owner device"
        np.testing.assert_array_equal(store._fetch(*k), pre_vals[k])


def fleet_case_load_accounting(shards=4, n_queries=7, seed=2, lose_at=40,
                               lose_worker=2):
    """Satellite: ``_load`` is O(1) counter-backed and must equal the brute
    placement-map scan at every tick — across submits, query completions
    (both the device round and the host skip fast path) and a mid-run
    worker loss rebalance."""
    from repro import api as rexcam
    from repro.core.policy import SearchPolicy

    _require_devices(shards)

    def brute(eng, worker):
        return sum(1 for qid, w in eng._placement.items()
                   if w == worker and qid in eng.queries
                   and not eng.queries[qid].done)

    world = make_serving_world(seed=seed, n_queries=n_queries)
    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60, replay_skip=2)   # exercise _skip_round
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, policy=policy,
                       geo_adj=world["net"].geo_adjacent, shards=shards)
    q_vids = world["q_vids"]
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
        assert all(eng._load(w) == brute(eng, w) for w in eng._workers)
    for step, t in enumerate(range(t0, vis.horizon + 500)):
        if step == lose_at:
            eng.lose_worker(lose_worker)
        if t < vis.horizon:
            frames = {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
            eng.ingest(frames)
        eng.tick()
        assert all(eng._load(w) == brute(eng, w) for w in eng._workers), \
            f"load counters diverged from the placement scan at step {step}"
        if all(q.done for q in eng.queries.values()):
            break
    assert all(q.done for q in eng.queries.values())
    assert all(eng._load(w) == 0 for w in eng._workers)


def fleet_property_suite(max_examples=6):
    """Satellite property test, shared between the in-process (8-device CI
    step) and subprocess entry: random scheme/seed/shard-count/replay-skip
    draws must keep the fleet bit-identical to one engine.  Uses real
    hypothesis when importable, else the deterministic fallback shim."""
    sys.path.insert(0, os.path.dirname(__file__))
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

    from repro.core.policy import SearchPolicy

    singles: dict[tuple, tuple] = {}   # (seed, policy) -> reference run

    @settings(max_examples=max_examples, deadline=None)
    @given(st.sampled_from(["rexcam", "all", "spatial_only", "geo"]),
           st.integers(0, 2),                  # world seed stream
           st.sampled_from([1, 2, 4, 8]),      # shard counts
           st.sampled_from([1, 2]))            # §5.3 skip mode on/off
    def prop(scheme, seed, shards, replay_skip):
        world = make_serving_world(n_entities=80, horizon=300, seed=seed,
                                   n_queries=3)
        policy = SearchPolicy(scheme=scheme, s_thresh=.05, t_thresh=.02,
                              exit_t=60, replay_skip=replay_skip)
        key = (seed, policy)
        _, singles[key] = assert_fleet_trace_identical(
            world, policy, shards, single=singles.get(key))

    prop()


def fleet_case_recompile_guard(shard_counts=(1, 2, 4, 8), n_queries=5,
                               seed=0, warmup=150, steady=150):
    """Compile-discipline case (tests/test_analysis.py + the CI fleet step):
    for every shard count, the serving loop's jit entries — module-level
    AND the fleet's shard_map step bodies — compile each abstract signature
    at most ONCE after warmup.  ``RecompileGuard`` raises on steady-state
    cache misses; warmup absorbs tracing plus the batch/gallery high-water
    marks' growth phase (the hwm layout keeps shapes monotone, so by steady
    state the signature set is frozen up to one genuinely-new shape class
    per entry)."""
    from repro import api as rexcam
    from repro.analysis import RecompileGuard
    from repro.core.policy import SearchPolicy

    _require_devices(max(shard_counts))
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    vis, gal, feats = world["vis"], world["gal"], world["feats"]
    q_vids = world["q_vids"]
    for shards in shard_counts:
        eng = rexcam.serve(world["model"], embed_fn=lambda x: x,
                           policy=policy,
                           geo_adj=world["net"].geo_adjacent, shards=shards)
        t0 = int(vis.t_out[q_vids].min())
        eng.t = t0
        for i, q in enumerate(q_vids):
            eng.submit_query(i, feats[q], int(vis.cam[q]),
                             int(vis.t_out[q]))

        def run(ticks, start):
            for t in range(start, start + ticks):
                if t < vis.horizon:
                    frames = {}
                    for c in range(vis.n_cams):
                        vids = gal[c, t][gal[c, t] >= 0]
                        if len(vids):
                            frames[c] = feats[vids]
                    eng.ingest(frames)
                eng.tick()

        run(warmup, t0)
        with RecompileGuard.for_engine(eng, max_new=1,
                                       label=f"shards={shards}"):
            run(steady, t0 + warmup)


def _fake_rpc_factory(profiles=None, **kw):
    """Zero-arg factory for a VIRTUAL-clock ``FakeRpcTransport`` — each
    drive gets fresh transport state and injected latency costs no real
    wall time.  ``profiles`` maps peer -> FaultProfile kwargs."""
    def make():
        from repro.runtime.transport import (FakeRpcTransport, FaultProfile,
                                             manual_clock)
        clock, sleep = manual_clock()
        faults = {w: FaultProfile(**p) for w, p in (profiles or {}).items()}
        kw2 = dict(kw)
        if isinstance(kw2.get("default"), dict):
            kw2["default"] = FaultProfile(**kw2["default"])
        return FakeRpcTransport(faults=faults, clock=clock, sleep=sleep, **kw2)
    return make


def fleet_case_transport_shard_counts(shard_counts=(1, 2, 4, 8), n_queries=5,
                                      seed=0):
    """The transport differential across the whole shard matrix: a fake-RPC
    fleet with per-peer latency+jitter AND the prefetch pipeline on stays
    bit-identical to the single engine for shards {1, 2, 4, 8}; the named
    in-proc transport (with and without prefetch) likewise.  Transport must
    change WHEN blocks arrive, never WHAT is ranked."""
    from repro.core.policy import SearchPolicy
    from repro.runtime.transport import InProcTransport

    _require_devices(max(shard_counts))
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    fake = _fake_rpc_factory(default=dict(latency=.01, jitter=.005))
    single = None
    for shards in shard_counts:
        eng, single = assert_fleet_trace_identical(
            world, policy, shards, single=single, transport=fake,
            prefetch=True)
        c = eng.gallery.counters()
        assert c["remote_fetches"] > 0, "no fetch ever crossed the transport"
        assert c["dead_peers"] == 0 and c["timeouts"] == 0
        # cache-hit parity: every hit was served through the fetch plane,
        # either prefetched or as the blocking fallback
        assert c["prefetch_hits"] <= eng.cache_hits
    eng, _ = assert_fleet_trace_identical(world, policy, 4, single=single,
                                          transport=InProcTransport,
                                          prefetch=True)
    assert eng.gallery.counters()["remote_fetches"] > 0
    assert_fleet_trace_identical(world, policy, 4, single=single,
                                 transport=InProcTransport, prefetch=False)


def fleet_case_transport_faults(shards=4, n_queries=5, seed=0):
    """The fault-injection matrix, each configuration trace-identical to
    the single engine: drop+retry (lost attempts re-issue after
    timeout+backoff), reorder (responses overtake each other), and blocking
    heavy latency with no prefetch (pure slowdown)."""
    from repro.core.policy import SearchPolicy

    _require_devices(shards)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    single = None
    cases = [
        ("drop+retry",
         _fake_rpc_factory(default=dict(latency=.01, drop=.3),
                           timeout=.05, max_retries=6), True),
        ("reorder",
         _fake_rpc_factory(default=dict(latency=.01, jitter=.01, reorder=.5,
                                        reorder_delay=.2),
                           timeout=1.0), True),
        ("blocking-latency",
         _fake_rpc_factory(default=dict(latency=.05)), False),
    ]
    for name, factory, prefetch in cases:
        eng, single = assert_fleet_trace_identical(
            world, policy, shards, single=single, transport=factory,
            prefetch=prefetch)
        c = eng.gallery.counters()
        assert c["remote_fetches"] > 0, f"{name}: transport never used"
        assert c["dead_peers"] == 0, f"{name}: a peer unexpectedly died"
        if name == "drop+retry":
            assert c["retries"] > 0 and c["timeouts"] > 0, \
                "drop=.3 produced no retries — fault injection inert"
        # per-worker fetch traffic is surfaced in the shard report
        rep = eng.shard_report()
        assert sum(r["remote_fetches"] for r in rep) == c["remote_fetches"]


def fleet_case_transport_timeout_rehome(shards=4, n_queries=6, seed=1,
                                        warmup=None):
    """timeout -> dead-peer -> rehome, end to end: one peer drops EVERY
    attempt, so the first fetch against it exhausts the retry budget
    mid-round, fires ``on_dead``, the gallery re-homes immediately (the
    blocked fetch retries against the new owner and succeeds), and the
    fleet scales down at the end of the tick — trace stays bit-identical."""
    from repro.core.policy import SearchPolicy

    _require_devices(shards)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    victim = "w1"
    factory = _fake_rpc_factory({victim: dict(drop=1.0)},
                                timeout=.05, max_retries=2, backoff=.01)
    eng, _ = assert_fleet_trace_identical(world, policy, shards,
                                          transport=factory, prefetch=False)
    c = eng.gallery.counters()
    assert c["dead_peers"] == 1, \
        f"the all-drop peer never died (counters: {c})"
    assert c["timeouts"] >= 3 and c["retries"] >= 2
    assert victim not in eng._workers, "dead peer still in the fleet"
    assert eng.n_shards == shards - 1
    assert victim not in set(eng.gallery._owner.values()), \
        "dead peer still owns cameras"
    assert eng.gallery.rehomed_blocks > 0 or c["remote_fetches"] > 0


def fleet_case_transport_midfetch_loss(shards=4, lose_at=50, lose_worker=1,
                                       n_queries=7, seed=1):
    """Mid-fetch worker loss: with prefetch handles in flight, the fleet
    loses a worker (``lose_worker`` marks the peer dead on the transport) —
    in-flight handles to it fail fast with ``PeerDeadError`` at consume
    time and the round falls back to a blocking fetch from the re-homed
    owner.  Trace stays bit-identical; waste is exactly accounted."""
    from repro.core.policy import SearchPolicy

    _require_devices(shards)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    world = make_serving_world(seed=seed, n_queries=n_queries)
    factory = _fake_rpc_factory(default=dict(latency=.01, jitter=.005))
    eng, _ = assert_fleet_trace_identical(
        world, policy, shards, lose_at=lose_at, lose_worker=lose_worker,
        transport=factory, prefetch=True)
    tr = eng.gallery.transport
    assert tr.is_dead(f"w{lose_worker}"), \
        "lose_worker did not mark the peer dead on the transport"
    c = eng.gallery.counters()
    assert c["prefetch_hits"] > 0, "prefetch never served a block"
    assert f"w{lose_worker}" not in set(eng.gallery._owner.values())


@pytest.fixture(scope="session")
def duke_sim():
    """Small-but-real duke-like scenario shared across tests (session-cached)."""
    from repro.core import (duke_like_network, simulate_network, build_gallery,
                            build_model)
    from repro.core.features import FeatureParams, make_features
    from repro.core.tracker import make_queries

    net = duke_like_network()
    vis = simulate_network(net, n_entities=900, horizon=2400, seed=0)
    gal, _ = build_gallery(vis, max_slots=24)
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                        time_limit=1600)
    feats, emb = make_features(vis, 900, FeatureParams())
    q_vids, gt_vids = make_queries(vis, 40, seed=1)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids)
