import os
import sys

# NOTE: no xla_force_host_platform_device_count here — smoke tests and benches
# must see 1 device.  Sharding tests spawn subprocesses that set the flag.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def duke_sim():
    """Small-but-real duke-like scenario shared across tests (session-cached)."""
    from repro.core import (duke_like_network, simulate_network, build_gallery,
                            build_model)
    from repro.core.features import FeatureParams, make_features
    from repro.core.tracker import make_queries

    net = duke_like_network()
    vis = simulate_network(net, n_entities=900, horizon=2400, seed=0)
    gal, _ = build_gallery(vis, max_slots=24)
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                        time_limit=1600)
    feats, emb = make_features(vis, 900, FeatureParams())
    q_vids, gt_vids = make_queries(vis, 40, seed=1)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids)
