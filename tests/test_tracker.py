"""Tracker (Algorithm 1) behaviour on hand-built deterministic scenarios."""
import numpy as np
import pytest

from repro.core import TrackerParams, build_model, track_queries
from repro.core.simulate import Visits
from repro.core.tracker import make_queries


def _toy_world():
    """2 entities walking 0 -> 1 -> 2 on a 3-camera corridor, well separated.

    History (entities 0..19) trains the profile; entities 20, 21 are tracked.
    Travel time is exactly 10 steps, dwell 5.
    """
    ents, cams, tin, tout = [], [], [], []
    t0 = 0
    for e in range(22):
        t = t0 + e * 40
        for c in range(3):
            ents.append(e)
            cams.append(c)
            tin.append(t)
            tout.append(t + 5)
            t += 5 + 10  # dwell 5, travel 10
    horizon = max(tout) + 50
    vis = Visits(np.array(ents), np.array(cams), np.array(tin),
                 np.array(tout), horizon, 3)
    # orthogonal features: perfect re-id
    feats = np.zeros((len(vis), 64), np.float32)
    for v in range(len(vis)):
        feats[v, vis.ent[v] % 64] = 1.0
    gal = np.full((3, horizon, 4), -1, np.int32)
    fill = np.zeros((3, horizon), np.int32)
    for v in range(len(vis)):
        for t in range(vis.t_in[v], vis.t_out[v] + 1):
            gal[vis.cam[v], t, fill[vis.cam[v], t]] = v
            fill[vis.cam[v], t] += 1
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, 3,
                        time_limit=20 * 40)
    return vis, gal, feats, model


def test_perfect_world_full_recall():
    vis, gal, feats, model = _toy_world()
    q_vids, gt_vids = make_queries(vis, 2, seed=0)
    p = TrackerParams(scheme="rexcam", s_thresh=0.3, t_thresh=0.02, exit_t=60)
    r = track_queries(model, vis, gal, feats, q_vids, gt_vids, p)
    assert r.recall == 1.0
    assert r.precision == 1.0
    assert r.rescued.sum() == 0         # no pruning errors in a clean world
    assert r.mean_delay == 0.0


def test_filtered_cost_below_baseline():
    vis, gal, feats, model = _toy_world()
    q_vids, gt_vids = make_queries(vis, 2, seed=0)
    r_all = track_queries(model, vis, gal, feats, q_vids, gt_vids,
                          TrackerParams(scheme="all", exit_t=60))
    r_rex = track_queries(model, vis, gal, feats, q_vids, gt_vids,
                          TrackerParams(scheme="rexcam", s_thresh=0.3,
                                        t_thresh=0.02, exit_t=60))
    assert r_rex.total_cost < r_all.total_cost
    assert r_rex.recall == r_all.recall == 1.0


def test_cost_is_camera_frames():
    """Baseline cost = C * steps_searched exactly in a world with one query."""
    vis, gal, feats, model = _toy_world()
    q_vids, gt_vids = make_queries(vis, 1, seed=0)
    p = TrackerParams(scheme="all", exit_t=30)
    r = track_queries(model, vis, gal, feats, q_vids, gt_vids, p)
    assert r.cost[0] % 3 == 0           # multiples of C=3
    assert r.cost[0] > 0


def test_self_window_tracks_current_camera():
    """A query whose entity is still visible must re-match instantly."""
    vis, gal, feats, model = _toy_world()
    q_vids, gt_vids = make_queries(vis, 2, seed=0)
    p = TrackerParams(scheme="rexcam", s_thresh=0.3, t_thresh=0.02,
                      exit_t=60, self_window=6)
    r = track_queries(model, vis, gal, feats, q_vids, gt_vids, p)
    assert r.n_match.sum() > 2 * 2      # multiple matches per visit


def test_make_queries_gt_is_future_only(duke_sim):
    vis = duke_sim["vis"]
    q_vids, gt_vids = duke_sim["q_vids"], duke_sim["gt_vids"]
    for i, q in enumerate(q_vids):
        for g in gt_vids[i]:
            if g >= 0:
                assert vis.ent[g] == vis.ent[q]
                assert vis.t_in[g] > vis.t_out[q]


def test_track_result_metrics_consistent(duke_sim):
    r = track_queries(duke_sim["model"], duke_sim["vis"], duke_sim["gal"],
                      duke_sim["feats"], duke_sim["q_vids"], duke_sim["gt_vids"],
                      TrackerParams(scheme="rexcam"),
                      geo_adj=duke_sim["net"].geo_adjacent)
    assert (r.n_correct <= r.n_match).all()
    assert (r.visit_hits.sum(1) <= r.gt_count).all()
    assert 0.0 <= r.recall <= 1.0 and 0.0 <= r.precision <= 1.0
    assert (r.delay >= 0).all()
