"""The static invariant plane (repro.analysis): lint rules REX001-005 on
the planted-violation fixture corpus, the jaxpr audit over every registered
jit entry, the Pallas kernel audit, RecompileGuard, and the REPRO_SANITIZE
runtime assertions.

The fixture corpus under ``tests/fixtures/analysis`` mirrors the source
layout (runtime/, core/, kernels/) because the rules scope by path; every
fixture declares its expected hits in ``# rex-expect: REXNNN=n`` headers
and the tests assert EXACT counts — a rule firing once too often is as red
as one that stopped firing.
"""
import os
import re
import subprocess
import sys

import numpy as np
import pytest

TESTS = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.abspath(os.path.join(TESTS, ".."))
SRC = os.path.join(REPO, "src")
FIXTURES = os.path.join(TESTS, "fixtures", "analysis")

_EXPECT_RE = re.compile(r"#\s*rex-expect:\s*(REX\d+)\s*=\s*(\d+)")


def _fixture_files():
    out = []
    for dirpath, _dirs, files in os.walk(FIXTURES):
        for f in sorted(files):
            if f.endswith(".py"):
                path = os.path.join(dirpath, f)
                out.append((os.path.relpath(path, FIXTURES), path))
    return out


# ---------------------------------------------------------------------------
# REX lint rules on the fixture corpus
# ---------------------------------------------------------------------------

def test_fixture_corpus_exact_counts():
    """Every fixture's per-rule violation count matches its rex-expect
    header exactly (0 for undeclared rules) — suppressed and clean lines
    must stay quiet, planted lines must all fire."""
    from repro.analysis.lint import RULES, lint_file

    assert _fixture_files(), "fixture corpus missing"
    fired = set()
    for rel, path in _fixture_files():
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        expected: dict[str, int] = {}
        for rule, n in _EXPECT_RE.findall(text):
            expected[rule] = expected.get(rule, 0) + int(n)
        got: dict[str, int] = {}
        for v in lint_file(path, text=text, virtual_path=rel):
            got[v.rule] = got.get(v.rule, 0) + 1
            fired.add(v.rule)
        assert got == expected, \
            f"{rel}: expected {expected}, linted {got}"
    # the corpus demonstrates every named rule at least once
    assert fired == set(RULES), f"rules never fired: {set(RULES) - fired}"


def test_clean_fixtures_are_quiet():
    from repro.analysis.lint import lint_file
    for name in ("runtime/clean_engine.py", "core/suppressed.py"):
        path = os.path.join(FIXTURES, *name.split("/"))
        assert lint_file(path, virtual_path=name) == []


def test_suppression_scopes():
    """Line-level, def-level and file-level ``# rex: disable`` all hold:
    the REX001 fixture plants three heavy-numpy calls but only the
    unsuppressed one (line-level + def-level waived) reports."""
    from repro.analysis.lint import lint_file
    path = os.path.join(FIXTURES, "runtime", "hot_numpy.py")
    vs = lint_file(path, virtual_path="runtime/hot_numpy.py")
    assert [v.rule for v in vs] == ["REX001"]
    assert "np.linalg.norm" in vs[0].msg


def test_violation_rendering_is_greppable():
    from repro.analysis.lint import Violation
    v = Violation("REX001", "runtime/engine.py", 42, "boom")
    assert str(v) == "runtime/engine.py:42: REX001 boom"


def test_repo_tree_is_lint_clean():
    """The gate's zero-at-HEAD half for the lint layer."""
    from repro.analysis.lint import lint_paths
    vs = lint_paths([os.path.join(SRC, "repro")], rel_to=REPO)
    assert vs == [], "\n".join(str(v) for v in vs)


def test_check_invariants_script_contract():
    """Exit-code contract of the CI gate: --fixtures exits NON-zero (the
    planted corpus demonstrates every rule), --only lint exits 0 at HEAD."""
    env = dict(os.environ, PYTHONPATH=SRC)
    script = os.path.join(REPO, "scripts", "check_invariants.py")
    r = subprocess.run([sys.executable, script, "--fixtures"],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode != 0, r.stdout + r.stderr
    assert "every rule demonstrated" in r.stdout
    r = subprocess.run([sys.executable, script, "--only", "lint"],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# jaxpr audit
# ---------------------------------------------------------------------------

def test_jaxpr_audit_clean_at_head():
    """Every registered jit entry (engine steps, kernel wrappers, the fleet
    shard_map bodies on a 1-device mesh) traces without forbidden
    primitives, x64 promotions, weak-typed outputs or dynamic shapes."""
    from repro.analysis.jaxpr_audit import audit_jaxprs
    vs = audit_jaxprs()
    assert vs == [], "\n".join(str(v) for v in vs)


def test_jaxpr_audit_flags_debug_callback():
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_closed_jaxpr

    @jax.jit
    def noisy(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    vs = audit_closed_jaxpr("noisy", noisy.trace(jnp.ones(3)).jaxpr)
    assert any("debug_callback" in v.msg for v in vs)


def test_jaxpr_audit_flags_weak_type_output():
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_closed_jaxpr

    @jax.jit
    def leaky(x):
        return x.sum(), 1.0        # python scalar output: weak-typed

    vs = audit_closed_jaxpr("leaky", leaky.trace(jnp.ones(3)).jaxpr)
    assert any("weak-typed" in v.msg for v in vs)


def test_jaxpr_audit_flags_f64():
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_closed_jaxpr

    @jax.jit
    def promote(x):
        return x.astype(jnp.float64) + 1

    with jax.experimental.enable_x64():
        traced = promote.trace(jnp.ones(3, jnp.float32))
    vs = audit_closed_jaxpr("promote", traced.jaxpr)
    assert any("float64" in v.msg for v in vs)


# ---------------------------------------------------------------------------
# RecompileGuard
# ---------------------------------------------------------------------------

def test_recompile_guard_trips_on_shape_polymorphism():
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import RecompileError, RecompileGuard

    @jax.jit
    def f(x):
        return x * 2

    f(jnp.ones(4))                      # warmup signature
    with RecompileGuard({"f": f}):
        f(jnp.ones(4))                  # same shape: cached, fine
    with pytest.raises(RecompileError, match=r"f: \+1"):
        with RecompileGuard({"f": f}):
            f(jnp.ones(8))              # new shape: steady-state recompile
    with RecompileGuard({"f": f}, max_new=1):
        f(jnp.ones(16))                 # one new shape class allowed


def test_recompile_guard_reports_deltas_without_raising_mid_block():
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import RecompileGuard

    @jax.jit
    def g(x):
        return x + 1

    g(jnp.ones(2))
    guard = RecompileGuard({"g": g}, max_new=2)
    with guard:
        g(jnp.ones(3))
        g(jnp.ones(5))
        assert guard.new_compiles() == {"g": 2}


def test_fleet_steady_state_compiles_once_across_shard_counts():
    """THE acceptance case: shard counts {1, 2, 4, 8} on 8 fake CPU
    devices, RecompileGuard over every registered entry plus the fleet's
    shard_map jits, at most one new signature per entry after warmup.
    Runs in-process on the CI fleet step, else in a flag-setting
    subprocess (the flag must not leak into this runtime)."""
    import jax
    if jax.local_device_count() >= 8:
        import conftest
        conftest.fleet_case_recompile_guard()
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, TESTS] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-c",
         "import conftest; conftest.fleet_case_recompile_guard()"],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


# ---------------------------------------------------------------------------
# kernel audit
# ---------------------------------------------------------------------------

def test_kernel_audit_clean_at_head():
    from repro.analysis.kernel_audit import audit_kernels
    vs = audit_kernels()
    assert vs == [], "\n".join(str(v) for v in vs)


def test_kernel_bounds_prover_flags_oob_index_map():
    from types import SimpleNamespace
    from repro.analysis.kernel_audit import check_record

    spec = SimpleNamespace(block_shape=(8, 8), index_map=lambda i, j: (i, j))
    rec = dict(kernel="bad", grid=(3, 2), in_specs=[spec], out_specs=None,
               out_shape=None, operand_shapes=[(16, 16)])
    vs = check_record(rec)        # grid point (2, 0) reads rows 16..24
    assert len(vs) == 1 and "out of bounds" in vs[0].msg

    rec["operand_shapes"] = [(24, 16)]
    assert check_record(rec) == []


def test_kernel_capture_intercepts_without_execution():
    import jax.numpy as jnp
    from repro.analysis.kernel_audit import _capture_call
    from repro.kernels.reid_topk import reid_topk

    calls = []
    q = jnp.asarray(np.random.default_rng(0).normal(size=(3, 8)), jnp.float32)
    g = jnp.asarray(np.random.default_rng(1).normal(size=(5, 8)), jnp.float32)
    records = _capture_call(reid_topk, q, g, 2)
    assert calls == []            # nothing ran
    assert len(records) == 1
    rec = records[0]
    assert rec["kernel"] == "_reid_kernel"
    assert rec["grid"] and rec["in_specs"]


# ---------------------------------------------------------------------------
# REPRO_SANITIZE runtime assertions
# ---------------------------------------------------------------------------

def test_sanitize_transport_reentrancy_assertion():
    """Armed: a fetch issued from inside the on_dead callback raises.
    Disarmed: the same callback is merely (dubious but) permitted."""
    from repro.analysis import sanitize
    from repro.runtime.transport import InProcTransport

    sanitize.enable()
    try:
        tr = InProcTransport()
        tr.on_dead = lambda peer: tr.fetch("w1", "k", lambda: 1)
        with pytest.raises(AssertionError, match="re-entered"):
            tr._fail_peer("w0")
    finally:
        sanitize.disable()

    tr2 = InProcTransport()
    got = []
    tr2.on_dead = lambda peer: got.append(tr2.fetch("w1", "k", lambda: 1))
    tr2._fail_peer("w0")
    assert got == [1]


def test_sanitize_env_latch_toggles_debug_nans():
    import jax
    from repro.analysis import sanitize

    before = bool(jax.config.jax_debug_nans)
    sanitize.enable()
    assert sanitize.enabled() and jax.config.jax_debug_nans
    sanitize.disable()
    assert not sanitize.enabled()
    assert bool(jax.config.jax_debug_nans) is False
    if before:                      # restore whatever the session had
        sanitize.enable()
