"""The fleet-vs-single-engine differential harness (the sharding contract).

``ShardedServingEngine`` must be TRACE-IDENTICAL to the single-process
``ServingEngine``: same admissions, same match indices/values (tie-breaks
included), same rescue attribution, same totals under both cost conventions
— for shard counts {1, 2, 4, 8}, query counts that don't divide the shard
count, and mid-run worker loss.  The case bodies live in ``tests/conftest.py``
so two entry points share them:

  * the CI ``fleet`` step runs this file directly under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu``
    — the cases then run IN-PROCESS on the 8 fake CPU devices;
  * under plain tier-1 (1 device; the flag must not leak into the other
    tests' jax runtime) each case re-enters the same conftest function in a
    subprocess that sets the flag.
"""
import os
import subprocess
import sys

TESTS = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.abspath(os.path.join(TESTS, "..", "src"))


def _fleet_case(fn_name: str, timeout=900, **kwargs):
    """Run ``conftest.<fn_name>(**kwargs)`` on >= 8 devices: in-process when
    this runtime already has them, else in a flag-setting subprocess."""
    import jax

    if jax.local_device_count() >= 8:
        import conftest
        getattr(conftest, fn_name)(**kwargs)
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, TESTS] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    code = f"import conftest; conftest.{fn_name}(**{kwargs!r})"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, \
        f"{fn_name}{kwargs}:\nstdout:\n{r.stdout}\nstderr:\n{r.stderr}"


# ---------------------------------------------------------------------------
# the differential contract on 8 fake devices
# ---------------------------------------------------------------------------

def test_fleet_trace_identical_across_shard_counts():
    """Shard counts {1, 2, 4, 8}, 5 queries (not divisible by any of them),
    plus an exactly-divisible 4-query/4-shard pass."""
    _fleet_case("fleet_case_shard_counts")


def test_fleet_worker_loss_rebalances_without_divergence():
    """Mid-run worker loss: the data axis shrinks 4 -> 3, orphaned queries
    re-scatter over the survivors, the trace never diverges."""
    _fleet_case("fleet_case_worker_loss")


def test_fleet_consolidated_path_trace_identical():
    """The consolidation tentpole differential: the segment-ID ranking path
    (one ``reid_topk_segments`` call over the fleet-global RoundPlan) is
    bit-identical to the UNCONSOLIDATED per-frame reference engine across
    shard counts {1, 2, 4, 8}, a non-divisible query count, and a mid-run
    worker loss."""
    _fleet_case("fleet_case_consolidation")


def test_fleet_tile_path_trace_identical_to_camera_path():
    """The sub-frame spatial admission differential: ``tile_grid=T`` over a
    tile-less model (all tiles admitted) is bit-identical to camera-granular
    serving for the single engine and shard counts {1, 2, 4, 8}, through a
    mid-run worker loss, with the tile counters tiling T*T exactly."""
    _fleet_case("fleet_case_tiles")


def test_round_plan_conserves_admission_mass():
    """Satellite regression: sum(want_count) == plan.admitted == the
    engine's admitted_steps accrual, across consolidate on/off and shard
    counts {1, 2, 4, 8} — the RoundPlan may never create or lose an
    admission step."""
    _fleet_case("fleet_case_plan_conservation", timeout=1200)


def test_fleet_random_streams_property():
    """Satellite property test: random scheme/seed/shard-count/skip draws
    stay bit-identical (deterministic via tests/_hypothesis_fallback.py
    when real hypothesis is absent)."""
    _fleet_case("fleet_property_suite", max_examples=6)


def test_fleet_recalibration_epoch_boundaries():
    """§6 recalibration differential: a mid-run topology shift trips the
    drift trigger, the controller hot-swaps a re-profiled M, and the fleet
    stays bit-identical to the single engine INCLUDING the model-epoch
    stamps in every trace record — the swap lands on the same round on
    every shard of the mesh."""
    _fleet_case("fleet_case_recalibration")


def test_fleet_gallery_modes_differential():
    """The gallery-plane contract: sharded AND replicated-local gallery
    fleets are trace-identical to the single engine, and a counting
    embed_fn shows fleet-global embed calls equal the single engine's —
    no (cam, frame) pair ever embedded twice fleet-wide."""
    _fleet_case("fleet_case_gallery_modes")


def test_fleet_gallery_rehome_on_worker_loss():
    """Worker loss migrates the lost worker's gallery shard (cameras +
    device-resident blocks) onto survivors, bit-exactly."""
    _fleet_case("fleet_case_gallery_rehome")


def test_fleet_load_accounting_o1():
    """Satellite: the O(1) per-worker live-load counters match the brute
    placement scan at every tick, across completions and a rebalance."""
    _fleet_case("fleet_case_load_accounting")


# ---------------------------------------------------------------------------
# fleet machinery that needs no fake-device mesh (tier-1, in-process)
# ---------------------------------------------------------------------------

def test_fleet_single_shard_matches_engine_inprocess():
    """shards=1 exercises the whole fleet path (mesh build, shard_map
    dispatch, placement, per-shard accounting) on any device count."""
    from conftest import (assert_fleet_trace_identical, make_serving_world)
    from repro.core.policy import SearchPolicy

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    policy = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                          exit_t=60)
    eng, _ = assert_fleet_trace_identical(world, policy, shards=1)
    assert eng.n_shards == 1
    rep = eng.shard_report()
    assert len(rep) == 1 and rep[0]["alive"]
    assert rep[0]["admitted_steps"] == eng.admitted_steps
    # one shard sees the globally-deduplicated demand exactly
    assert rep[0]["unique_frames"] == eng.unique_frames


def test_api_serve_shards_knob():
    """The facade routes shards=None to the single engine and shards=k to
    the fleet; an infeasible shard count fails loudly."""
    import jax
    import pytest
    from repro import api as rexcam
    from repro.runtime.engine import ServingEngine
    from repro.runtime.fleet import ShardedServingEngine
    from conftest import make_serving_world

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    single = rexcam.serve(world["model"], embed_fn=lambda x: x)
    assert type(single) is ServingEngine
    fleet = rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1)
    assert isinstance(fleet, ShardedServingEngine)
    with pytest.raises(ValueError):
        rexcam.serve(world["model"], embed_fn=lambda x: x,
                     shards=len(jax.devices()) + 1)


def test_fleet_placement_and_loss_bookkeeping():
    """Host-side control plane alone (no ticks): least-loaded placement,
    orphan re-scatter on loss, and the last worker being irremovable."""
    import pytest
    from repro import api as rexcam
    from conftest import make_serving_world

    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    vis, feats = world["vis"], world["feats"]
    eng = rexcam.serve(world["model"], embed_fn=lambda x: x, shards=1)
    for i, q in enumerate(world["q_vids"][:2]):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    assert set(eng._placement) == {0, 1}
    assert set(eng._placement.values()) == {"w0"}
    with pytest.raises(RuntimeError):
        eng.lose_worker("w0")          # never drop the whole fleet
    with pytest.raises(KeyError):
        eng.lose_worker("w7")


def test_fleet_heartbeat_drives_scale_down():
    """poll_health: a dead worker (fake clock) leaves the fleet and its
    queries re-scatter — the HeartbeatMonitor wiring, no mesh math."""
    import jax
    import pytest
    from repro import api as rexcam
    from repro.runtime.cluster import HeartbeatMonitor
    from repro.runtime.fleet import ShardedServingEngine
    from repro.runtime.engine import EngineConfig
    from conftest import make_serving_world

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (covered by the CI fleet step)")
    world = make_serving_world(n_entities=60, horizon=240, seed=3,
                               n_queries=2)
    # a monitor that doesn't track the fleet's worker ids is a construction
    # error, not a silent poll_health no-op
    with pytest.raises(ValueError):
        ShardedServingEngine(world["model"], lambda x: x, EngineConfig(),
                             shards=2,
                             monitor=HeartbeatMonitor(["hostA", "hostB"]))
    now = [0.0]
    mon = HeartbeatMonitor(["w0", "w1"], timeout=10.0, clock=lambda: now[0])
    eng = ShardedServingEngine(world["model"], lambda x: x, EngineConfig(),
                               shards=2, monitor=mon)
    vis, feats = world["vis"], world["feats"]
    for i, q in enumerate(world["q_vids"][:2]):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    assert set(eng._placement.values()) == {"w0", "w1"}
    now[0] = 5.0
    mon.heartbeat("w0")
    now[0] = 15.0                      # w1 silent past the timeout
    assert eng.poll_health() == ["w1"]
    assert eng.n_shards == 1
    assert set(eng._placement.values()) == {"w0"}
    assert eng.poll_health() == []     # already removed: no double-fire
