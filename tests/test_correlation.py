"""Profiler + spatio-temporal model properties (unit + hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.core.correlation import INF_TIME
from repro.core.profiler import (build_model, profiling_cost, subsample_visits,
                                 transitions_from_visits)

# -- strategies -------------------------------------------------------------

@st.composite
def visit_tables(draw, max_ents=12, max_visits=60, n_cams=5, horizon=600):
    n = draw(st.integers(1, max_visits))
    ent = draw(st.lists(st.integers(0, max_ents - 1), min_size=n, max_size=n))
    cam = draw(st.lists(st.integers(0, n_cams - 1), min_size=n, max_size=n))
    t_in, t_out, cur = [], [], {}
    for i in range(n):
        start = cur.get(ent[i], 0) + draw(st.integers(1, 40))
        dur = draw(st.integers(1, 20))
        t_in.append(start)
        t_out.append(start + dur)
        cur[ent[i]] = start + dur
    return (np.array(ent), np.array(cam), np.array(t_in), np.array(t_out), n_cams)


@settings(max_examples=40, deadline=None)
@given(visit_tables())
def test_spatial_rows_are_substochastic(tab):
    ent, cam, t_in, t_out, C = tab
    m = build_model(ent, cam, t_in, t_out, C)
    S = np.asarray(m.S)
    ex = np.asarray(m.exit_frac)
    assert (S >= -1e-6).all()
    # rows + exit fraction sum to 1 for cameras with outbound traffic, 0 else
    total = S.sum(1) + ex
    counts = np.asarray(m.counts).sum(1) + ex * 0  # cameras with transitions
    for c in range(C):
        assert total[c] == pytest.approx(1.0, abs=1e-5) or total[c] == pytest.approx(0.0, abs=1e-6)


@settings(max_examples=40, deadline=None)
@given(visit_tables())
def test_cdf_monotone_and_bounded(tab):
    ent, cam, t_in, t_out, C = tab
    m = build_model(ent, cam, t_in, t_out, C)
    cdf = np.asarray(m.cdf)
    assert (np.diff(cdf, axis=-1) >= -1e-6).all()
    assert (cdf <= 1.0 + 1e-6).all() and (cdf >= -1e-6).all()


@settings(max_examples=40, deadline=None)
@given(visit_tables())
def test_transition_conservation(tab):
    """Each entity with k visits contributes exactly k-1 transitions + 1 exit."""
    ent, cam, t_in, t_out, C = tab
    src, dst, dt, exits, entries = transitions_from_visits(ent, cam, t_in, t_out)
    n_ents = len(np.unique(ent))
    assert len(src) == len(ent) - n_ents
    assert len(exits) == n_ents
    assert len(entries) == n_ents
    assert (dt >= 0).all()


@settings(max_examples=30, deadline=None)
@given(visit_tables(), st.integers(2, 10))
def test_subsampling_only_drops_or_quantizes(tab, k):
    ent, cam, t_in, t_out, C = tab
    e2, c2, i2, o2 = subsample_visits(ent, cam, t_in, t_out, k)
    assert len(e2) <= len(ent)
    assert ((i2 % k) == 0).all() and ((o2 % k) == 0).all()
    assert (i2 <= o2).all()


def test_f0_is_min_travel_time():
    ent = np.array([0, 0, 1, 1])
    cam = np.array([0, 1, 0, 1])
    t_in = np.array([0, 20, 100, 150])
    t_out = np.array([5, 25, 110, 160])
    m = build_model(ent, cam, t_in, t_out, 2)
    assert int(m.f0[0, 1]) == 15  # min(20-5, 150-110)
    assert int(m.f0[1, 0]) == int(INF_TIME)


def test_window_end_monotone_in_threshold():
    ent = np.repeat(np.arange(50), 2)
    rng = np.random.default_rng(0)
    cam = np.tile([0, 1], 50)
    t_in = np.empty(100, np.int64)
    t_out = np.empty(100, np.int64)
    for e in range(50):
        a = e * 100
        travel = int(rng.normal(40, 8))
        t_in[2 * e], t_out[2 * e] = a, a + 5
        t_in[2 * e + 1], t_out[2 * e + 1] = a + 5 + travel, a + 15 + travel
    m = build_model(ent, cam, t_in, t_out, 2)
    w_tight = np.asarray(m.window_end(0.01, 0.10))
    w_loose = np.asarray(m.window_end(0.01, 0.01))
    assert (w_tight <= w_loose).all()


def test_temporal_mask_respects_f0(duke_sim):
    m = duke_sim["model"]
    import jax.numpy as jnp
    cs = jnp.asarray(0)
    early = np.asarray(m.temporal_mask(cs, jnp.asarray(1), 0.02))
    f0 = np.asarray(m.f0[0])
    assert not early[f0 > 1].any()


def test_profiling_cost_scales_with_sampling(duke_sim):
    vis = duke_sim["vis"]
    full = profiling_cost(vis.ent, vis.cam, vis.t_in, vis.t_out, 1)
    half = profiling_cost(vis.ent, vis.cam, vis.t_in, vis.t_out, 2)
    assert full == pytest.approx(2 * half, rel=0.01)


def test_drift_score_all_zero_rescues_is_zero_no_warning():
    """Regression: a fresh engine (no replays yet) hands drift_score an
    all-zero rescue matrix — the score must be exactly 0.0 everywhere with
    no divide-by-zero warning, even unsmoothed on a model whose count
    matrix has zero-count pairs."""
    import warnings
    from repro.core.profiler import drift_score

    ent = np.array([0, 0])
    cam = np.array([0, 1])
    m = build_model(ent, cam, np.array([0, 20]), np.array([5, 25]), 3)
    assert float(np.asarray(m.counts).min()) == 0.0   # zero-count pairs exist
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for smoothing in (3.0, 0.0):
            s = drift_score(m, np.zeros((3, 3)), smoothing=smoothing)
            assert s.shape == (3, 3) and (s == 0.0).all()


def test_drift_score_unsmoothed_zero_count_pair_stays_finite():
    """smoothing=0 with a rescue on a never-profiled pair: infinite surprise
    must come back as a large finite score (it should dominate), not inf."""
    from repro.core.profiler import drift_score

    ent = np.array([0, 0])
    cam = np.array([0, 1])
    m = build_model(ent, cam, np.array([0, 20]), np.array([5, 25]), 3)
    rescues = np.zeros((3, 3))
    rescues[2, 0] = 1.0                               # count[2, 0] == 0
    s = drift_score(m, rescues, smoothing=0.0)
    assert np.isfinite(s).all()
    assert s[2, 0] == s.max() > 0


def test_potential_savings_positive(duke_sim):
    m = duke_sim["model"]
    s = m.potential_savings(0.05, 0.02)
    s_spatial = m.potential_savings(0.05, 0.0)
    assert s > s_spatial > 1.0
