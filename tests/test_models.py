"""Per-arch smoke tests: reduced same-family config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (decode_step, forward, init_params, lm_loss,
                          param_logical_axes, prefill)
from repro.optim import OptConfig, adamw_update, init_opt_state


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    batch = _batch(cfg, key)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(lm_loss, has_aux=True)(
            params, batch, cfg)
        params, opt, om = adamw_update(params, grads, opt, OptConfig(lr=1e-3))
        return params, opt, loss

    p1, o1, l1 = step(params, opt, batch)
    p2, o2, l2 = step(p1, o1, batch)
    assert jnp.isfinite(l1) and jnp.isfinite(l2)
    assert l2 < l1 + 0.5  # same batch twice: loss should not explode
    assert int(o2["step"]) == 2


@pytest.mark.parametrize("arch", ["deepseek_7b", "falcon_mamba_7b", "zamba2_2p7b",
                                  "whisper_tiny", "qwen2_vl_72b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits_full, _ = forward(params, batch, cfg)
    P = S - 3
    lg, state = prefill(params, dict(batch, tokens=batch["tokens"][:, :P]),
                        cfg, max_len=S)
    np.testing.assert_allclose(lg, logits_full[:, P - 1], rtol=2e-4, atol=2e-4)
    for i in range(P, S):
        lg, state = decode_step(params, state, batch["tokens"][:, i], cfg)
        np.testing.assert_allclose(lg, logits_full[:, i], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["phi3p5_moe_42b"])
def test_moe_decode_matches_forward_at_high_capacity(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits_full, _ = forward(params, batch, cfg)
    lg, state = prefill(params, dict(batch, tokens=batch["tokens"][:, :S - 1]),
                        cfg, max_len=S)
    np.testing.assert_allclose(lg, logits_full[:, S - 2], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The published configs carry the exact assigned hyperparameters."""
    spec = {
        "falcon_mamba_7b": dict(num_layers=64, d_model=4096, vocab_size=65024,
                                ssm_state=16, family="ssm"),
        "command_r_plus_104b": dict(num_layers=64, d_model=12288, num_heads=96,
                                    num_kv_heads=8, d_ff=33792, vocab_size=256000),
        "deepseek_7b": dict(num_layers=30, d_model=4096, num_heads=32,
                            num_kv_heads=32, d_ff=11008, vocab_size=102400),
        "phi3_medium_14b": dict(num_layers=40, d_model=5120, num_heads=40,
                                num_kv_heads=10, d_ff=17920, vocab_size=100352),
        "yi_6b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
                      d_ff=11008, vocab_size=64000),
        "zamba2_2p7b": dict(num_layers=54, d_model=2560, num_heads=32,
                            num_kv_heads=32, d_ff=10240, vocab_size=32000,
                            ssm_state=64, family="hybrid"),
        "qwen2_vl_72b": dict(num_layers=80, d_model=8192, num_heads=64,
                             num_kv_heads=8, d_ff=29568, vocab_size=152064,
                             mrope=True),
        "phi3p5_moe_42b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=6400, vocab_size=32064,
                               num_experts=16, experts_per_token=2),
        "qwen3_moe_30b": dict(num_layers=48, d_model=2048, num_heads=32,
                              num_kv_heads=4, d_ff=768, vocab_size=151936,
                              num_experts=128, experts_per_token=8),
        "whisper_tiny": dict(num_layers=4, d_model=384, num_heads=6,
                             num_kv_heads=6, d_ff=1536, vocab_size=51865,
                             encoder_layers=4, family="audio"),
    }[arch]
    cfg = get_config(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_match_param_tree(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    axes = param_logical_axes(cfg)

    def is_ax(x):
        return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)

    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=is_ax)
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert p.ndim == len(a), (p.shape, a)


def test_head_padding_is_inert():
    """phi3's 40->48 head padding must not change outputs vs grouped math."""
    cfg = get_smoke_config("phi3_medium_14b")  # 4 heads padded to 16
    assert cfg.num_padded_heads == 16 and cfg.num_heads == 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(0))
    logits, _ = forward(params, batch, cfg)
    # gradient through pad heads must be exactly zero
    def loss(p):
        return lm_loss(p, batch, cfg)[0]
    g = jax.grad(loss)(params)
    wq_g = g["layers"]["attn"]["wq"]         # (L, D, Hp*hd)
    hd = cfg.head_dim
    pad = wq_g[..., cfg.num_heads * hd:]
    assert jnp.abs(pad).max() == 0.0
