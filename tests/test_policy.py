"""The shared control plane (repro.core.policy): unit behaviour, the
tracker↔engine parity contract, and the engine's phase-2 replay rewind.

The parity test is the one that keeps the control-plane fork from
reopening: both consumers drive the SAME ``admit``/``advance`` and must
produce identical admission masks and phase transitions step for step.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import api as rexcam
from repro.core import build_gallery, build_model
from repro.core.policy import (PhaseState, SearchPolicy, admit, advance,
                               phase_windows)
from repro.core.simulate import Visits
from repro.core.tracker import make_queries, trace_queries
from test_tracker import _toy_world


# ---------------------------------------------------------------------------
# unit behaviour
# ---------------------------------------------------------------------------

def _state(c_q, f_q, f_curr, phase, live_f=None):
    n = len(c_q)
    return PhaseState(
        f_q=jnp.asarray(f_q, jnp.int32), c_q=jnp.asarray(c_q, jnp.int32),
        f_curr=jnp.asarray(f_curr, jnp.int32),
        phase=jnp.asarray(phase, jnp.int32),
        live_f=jnp.asarray(live_f if live_f is not None else f_curr, jnp.float32),
        done=jnp.zeros(n, jnp.bool_))


def test_phase2_relaxation_admits_superset(duke_sim):
    model = duke_sim["model"]
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02)
    s1 = _state([0, 3], [100, 200], [110, 215], [1, 1])
    s2 = _state([0, 3], [100, 200], [110, 215], [2, 2])
    m1 = np.asarray(admit(model, p, s1))
    m2 = np.asarray(admit(model, p, s2))
    assert (m2 | m1 == m2).all(), "relaxed phase-2 mask must be a superset"
    assert m2.sum() >= m1.sum()


def test_done_queries_admit_nothing(duke_sim):
    model = duke_sim["model"]
    p = SearchPolicy()
    s = _state([0], [100], [110], [1])
    s = PhaseState(**{**{f.name: getattr(s, f.name) for f in
                         type(s).__dataclass_fields__.values()},
                      "done": jnp.ones(1, jnp.bool_)})
    assert not np.asarray(admit(model, p, s)).any()


def test_advance_rewinds_on_phase1_exhaustion(duke_sim):
    """Alg. 1 line 21: exhausted phase-1 windows rewind to f_q + 1, relaxed."""
    model = duke_sim["model"]
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02, exit_t=240)
    w = phase_windows(model, p)
    c = 0
    f_q = 500
    el = int(np.asarray(w.w_end1)[c])           # el_next = el + 1 > w_end1
    s = _state([c], [f_q], [f_q + el], [1], live_f=[f_q + el])
    nxt = advance(p, w, s, jnp.zeros(1, bool), jnp.zeros(1, jnp.int32),
                  horizon=10 ** 6)
    assert int(nxt.phase[0]) == 2
    assert int(nxt.f_curr[0]) == f_q + 1        # the rewind
    assert not bool(nxt.done[0])


def test_advance_match_resets_to_phase1(duke_sim):
    model = duke_sim["model"]
    p = SearchPolicy()
    w = phase_windows(model, p)
    s = _state([2], [100], [140], [2], live_f=[160])
    nxt = advance(p, w, s, jnp.ones(1, bool), jnp.asarray([5], jnp.int32),
                  horizon=10 ** 6)
    assert int(nxt.phase[0]) == 1
    assert int(nxt.c_q[0]) == 5
    assert int(nxt.f_q[0]) == 140
    assert int(nxt.f_curr[0]) == 141


# ---------------------------------------------------------------------------
# tracker↔engine parity — the anti-fork contract
# ---------------------------------------------------------------------------

def _drive_engine(vis, gal, feats, model, q_vids, policy, extra_ticks=400,
                  retention=10 ** 6):
    eng = rexcam.serve(model, embed_fn=lambda x: x, policy=policy,
                       retention=retention)
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    trace = []
    for t in range(vis.horizon + extra_ticks):
        if t < vis.horizon:
            frames = {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
            eng.ingest(frames)
        eng.tick(record_trace=trace)
        if all(q.done for q in eng.queries.values()):
            break
    return eng, trace


def test_tracker_engine_admission_parity():
    """Same network, same queries: the batched tracker and the serving
    engine must emit IDENTICAL admission masks and phase transitions."""
    vis, gal, feats, model = _toy_world()
    q_vids, gt_vids = make_queries(vis, 2, seed=0)
    p = SearchPolicy(scheme="rexcam", s_thresh=0.3, t_thresh=0.02, exit_t=60)

    tr = trace_queries(model, vis, gal, feats, q_vids, gt_vids, p,
                       n_steps=2 * vis.horizon)
    eng, etrace = _drive_engine(vis, gal, feats, model, q_vids, p)

    for i in range(len(q_vids)):
        live = tr["live"][:, i]
        t_steps = [
            (int(tr["f_curr"][s, i]), int(tr["phase"][s, i]),
             tuple(tr["mask"][s, i]), bool(tr["matched"][s, i]),
             int(tr["match_cam"][s, i]) if tr["matched"][s, i] else -1)
            for s in np.flatnonzero(live)
        ]
        e_steps = [
            (rec["f_curr"], rec["phase"], tuple(rec["mask"]), rec["matched"],
             rec["match_cam"] if rec["matched"] else -1)
            for rec in etrace if rec["qid"] == i
        ]
        assert len(t_steps) > 20, "trace unexpectedly short"
        assert e_steps == t_steps, (
            f"query {i}: engine and tracker control planes diverged at step "
            f"{next(s for s, (a, b) in enumerate(zip(e_steps, t_steps)) if a != b)}")
        assert eng.queries[i].done


def test_tracker_engine_parity_all_scheme():
    """The baseline scheme runs through the same shared plane too."""
    vis, gal, feats, model = _toy_world()
    q_vids, gt_vids = make_queries(vis, 1, seed=0)
    p = SearchPolicy(scheme="all", exit_t=30)
    tr = trace_queries(model, vis, gal, feats, q_vids, gt_vids, p,
                       n_steps=2 * vis.horizon)
    eng, etrace = _drive_engine(vis, gal, feats, model, q_vids, p)
    live = tr["live"][:, 0]
    t_phases = [(int(tr["f_curr"][s, 0]), tuple(tr["mask"][s, 0]))
                for s in np.flatnonzero(live)]
    e_phases = [(rec["f_curr"], tuple(rec["mask"])) for rec in etrace]
    assert e_phases == t_phases


# ---------------------------------------------------------------------------
# engine phase-2 replay — the missed-detection rescue (§5.3)
# ---------------------------------------------------------------------------

def _rare_path_world(n_common=49, n_rare=1, travel=10, dwell=5):
    """3 cameras: c0->c1 dominates history (S≈0.98); c0->c2 is rare
    (S≈0.02 — below s_thresh=.05, above the relaxed .005).  The tracked
    entity takes the rare path, so phase 1 prunes the true camera and only
    the phase-2 replay can recover the sighting."""
    ents, cams, tin, tout = [], [], [], []
    t0 = 0
    n = n_common + n_rare + 1                   # +1 = the tracked entity
    for e in range(n):
        t = t0 + e * 40
        dst = 2 if (e >= n_common) else 1       # rare path for the last two
        for c in (0, dst):
            ents.append(e)
            cams.append(c)
            tin.append(t)
            tout.append(t + dwell)
            t += dwell + travel
    horizon = max(tout) + 60
    vis = Visits(np.array(ents), np.array(cams), np.array(tin),
                 np.array(tout), horizon, 3)
    feats = np.zeros((len(vis), 64), np.float32)
    for v in range(len(vis)):
        feats[v, vis.ent[v] % 64] = 1.0
    gal = np.full((3, horizon, 4), -1, np.int32)
    fill = np.zeros((3, horizon), np.int32)
    for v in range(len(vis)):
        for t in range(vis.t_in[v], vis.t_out[v] + 1):
            gal[vis.cam[v], t, fill[vis.cam[v], t]] = v
            fill[vis.cam[v], t] += 1
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, 3,
                        time_limit=(n - 1) * 40)
    return vis, gal, feats, model


def test_engine_replay_rescues_missed_detection():
    vis, gal, feats, model = _rare_path_world()
    S = np.asarray(model.S)
    assert S[0, 2] < 0.05 and S[0, 2] >= 0.005, S[0]  # rare but not absent

    q = len(vis) - 2                            # tracked entity's c0 visit
    assert vis.ent[q] == vis.ent[q + 1] and vis.cam[q + 1] == 2
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02, exit_t=120)

    def run(policy):
        eng = rexcam.serve(model, embed_fn=lambda x: x, policy=policy)
        eng.submit_query(0, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
        for t in range(vis.horizon):
            frames = {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
            eng.ingest(frames)
            eng.tick()
        return eng.queries[0]

    missed = run(SearchPolicy(**{**p.__dict__, "use_replay": False}))
    assert len(missed.matches) == 0, "phase-1 thresholds must prune c2"

    rescued = run(p)
    assert len(rescued.matches) > 0, "replay failed to recover the sighting"
    assert rescued.rescued > 0, "the recovery must be attributed to replay"
    assert rescued.matches[0][0] == 2            # found on the rare camera
    # the match frame is HISTORICAL: strictly behind the live frontier when
    # it was made (that is what 'replay from the FrameStore' means)
    assert rescued.matches[0][1] >= vis.t_in[q + 1]


def test_engine_embed_cache_never_reembeds():
    """Replay re-reads of still-retained frames are served from the
    FrameStore embedding cache: no (cam, t) pair ever reaches embed_fn
    twice, even though phase-2 rewinds revisit frames embedded live."""
    from collections import Counter

    vis, gal, feats, model = _rare_path_world()
    q = len(vis) - 2
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02, exit_t=120)
    H = vis.horizon
    embedded = Counter()
    ingested = {}

    def embed_fn(x):
        # crops carry a trailing (cam * H + t) tag column: count embeds per
        # (cam, frame) pair, then strip the tag
        for tag in x[:, -1]:
            embedded[int(tag)] += 1
        return x[:, :-1]

    # a persistent distractor on camera 1 (feature dim unused by any entity,
    # so it never matches): guarantees the live phase-1 pass embeds (c1, t)
    # frames that the phase-2 replay then re-reads
    distractor = np.zeros((1, feats.shape[1]), np.float32)
    distractor[0, 63] = 1.0

    eng = rexcam.serve(model, embed_fn=embed_fn, policy=p)
    eng.submit_query(0, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    for t in range(H):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            rows = [feats[vids]] if len(vids) else []
            if c == 1:
                rows.append(distractor)
            if rows:
                crops = np.concatenate(rows)
                tag = np.full((len(crops), 1), c * H + t, np.float32)
                frames[c] = np.concatenate([crops, tag], 1)
                ingested[c * H + t] = len(crops)
        eng.ingest(frames)
        eng.tick()

    assert eng.queries[0].rescued > 0        # replay really revisited history
    assert eng.cache_hits > 0                # ...and those re-reads hit cache
    for tag, n in embedded.items():
        assert n == ingested[tag], \
            f"frame {tag} embedded {n // ingested[tag]} times"


def test_engine_skip_short_circuit_equivalence():
    """The host fast path for sampled-out skip-mode rounds must be
    transition-identical to running them through admit/advance: identical
    traces, matches and terminal state with the short-circuit on and off."""
    from repro.runtime.engine import EngineConfig, ServingEngine

    vis, gal, feats, model = _rare_path_world()
    q = len(vis) - 2
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02, exit_t=120,
                     replay_skip=2)

    def run(short_circuit):
        cfg = EngineConfig(policy=p, short_circuit_skips=short_circuit)
        eng = ServingEngine(model, embed_fn=lambda x: x, cfg=cfg)
        eng.submit_query(0, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
        # second query anchored one frame earlier: opposite skip parity, so
        # replay rounds MIX gated and non-gated queries — the fast path must
        # keep per-round trace order identical to the slow path
        eng.submit_query(1, feats[q], int(vis.cam[q]), int(vis.t_out[q]) - 1)
        trace = []
        for t in range(vis.horizon):
            frames = {}
            for c in range(vis.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
            eng.ingest(frames)
            eng.tick(record_trace=trace)
        return eng, trace

    fast, tr_fast = run(True)
    slow, tr_slow = run(False)

    def steps(tr):
        return [(r["qid"], r["f_curr"], r["phase"], tuple(r["mask"]),
                 r["matched"], r["match_cam"] if r["matched"] else -1)
                for r in tr]

    assert steps(tr_fast) == steps(tr_slow)
    assert fast.skipped_steps > 0 and slow.skipped_steps == 0
    for qid in (0, 1):
        assert fast.queries[qid].matches == slow.queries[qid].matches
        assert (fast.queries[qid].done, fast.queries[qid].phase,
                fast.queries[qid].f_curr) == \
            (slow.queries[qid].done, slow.queries[qid].phase,
             slow.queries[qid].f_curr)
    # the whole point: gated rounds charge content steps but admit nothing
    assert fast.content_steps == slow.content_steps
    assert fast.admitted_steps == slow.admitted_steps


def test_engine_skip_mode_frame_counts_match_cost_model():
    """§5.3 skip-mode cost model: replay processes ~1-in-k content frames;
    the other (k-1)/k are short-circuited yet still charged as content."""
    vis, gal, feats, model = _rare_path_world()
    q = len(vis) - 2
    k = 3
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02, exit_t=120,
                     replay_skip=k)
    eng = rexcam.serve(model, embed_fn=lambda x: x, policy=p)
    eng.submit_query(0, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    for t in range(vis.horizon):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        eng.tick()
    assert eng.replay_steps > 0 and eng.skipped_steps > 0
    processed = eng.replay_steps - eng.skipped_steps
    ratio = processed / eng.replay_steps
    assert abs(ratio - 1 / k) < 0.15, \
        f"skip-mode processed {ratio:.2f} of replay steps, expected ~{1/k:.2f}"
    # every content step is charged: replay rounds = processed + skipped
    assert eng.content_steps >= eng.replay_steps


def test_engine_replay_miss_past_retention():
    """Rewinds past the ring buffer surface as replay_misses, not crashes."""
    vis, gal, feats, model = _rare_path_world()
    q = len(vis) - 2
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02, exit_t=120)
    eng = rexcam.serve(model, embed_fn=lambda x: x, policy=p, retention=2)
    eng.submit_query(0, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    for t in range(vis.horizon):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        eng.tick()
    assert eng.replay_misses > 0
    assert len(eng.queries[0].matches) == 0


@pytest.mark.parametrize("replay_speed", [3, 2.5])
def test_engine_replay_pacing_conserves_budget_on_midtick_catchup(
        replay_speed):
    """Regression (§5.3 pacing): a replayer that catches up MID-TICK runs
    its frontier round with replay budget still unspent — the engine must
    bank that remainder back into ``replay_credit`` instead of forfeiting
    it, or the realized content-rounds/tick undershoot ``replay_rate``
    long-run.  Conservation over a 200-tick always-lagging query: earned
    credit == spent content rounds + the credit still banked, within one
    round (the old code leaked ~1 round per catch-up tick, a deficit of
    dozens here)."""
    vis, gal, feats, model = _rare_path_world()
    q_vid = len(vis) - 2
    p = SearchPolicy(scheme="all", exit_t=100_000,
                     replay_speed=replay_speed)
    eng = rexcam.serve(model, embed_fn=lambda x: x, policy=p)
    eng.t = 5
    eng.submit_query(0, feats[q_vid], int(vis.cam[q_vid]), 0)
    q = eng.queries[0]
    T, shallow_ticks = 200, 0
    for step in range(T):
        # keep the query strictly lagging at every tick start (so credit is
        # never zeroed by the caught-up branch): shallow lag makes the
        # cursor catch the frontier mid-tick with budget to spare — the
        # forfeiture case — while a periodic deep jump drains the banked
        # credit as ordinary replay rounds
        lag = 50 if step % 5 == 4 else 1
        eng.t = max(eng.t, q.f_curr + lag)
        shallow_ticks += (eng.t - q.f_curr) < p.replay_rate
        eng.tick()
    assert not q.done
    assert shallow_ticks > 0, "no tick could catch up mid-round — inert"
    earned = p.replay_rate * T
    assert abs(earned - eng.content_steps - q.replay_credit) <= 1, \
        (f"pacing leak: earned {earned} rounds, realized "
         f"{eng.content_steps} + {q.replay_credit:.3f} banked")


def _drive_world(eng, vis, gal, feats):
    for t in range(vis.horizon):
        frames = {}
        for c in range(vis.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        eng.tick()


def test_engine_rescue_pairs_feed_drift_score():
    """§6 drift detection on the SERVING plane: the engine attributes every
    phase-2 rescue to its (anchor, match) camera pair, and
    ``profiler.drift_score`` over that live matrix spikes on exactly the
    drifted transition — entities taking a path the profile barely saw."""
    from repro.core.profiler import drift_score

    vis, gal, feats, model = _rare_path_world()
    q = len(vis) - 2                   # tracked entity takes the rare c0->c2
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02, exit_t=120)
    eng = rexcam.serve(model, embed_fn=lambda x: x, policy=p)
    eng.submit_query(0, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    _drive_world(eng, vis, gal, feats)

    assert eng.queries[0].rescued > 0
    # attribution: anchored at c0, recovered at c2 — nothing else
    assert eng.rescue_pairs[0, 2] == eng.queries[0].rescued
    assert eng.rescue_pairs.sum() == eng.queries[0].rescued
    score = np.asarray(drift_score(model, eng.rescue_pairs))
    assert score[0, 2] == score.max() > 0, "drifted pair must dominate"
    off = score.copy()
    off[0, 2] = 0.0
    assert (off == 0).all()


def test_engine_matched_stream_keeps_drift_score_flat():
    """The control: a stream the profile explains (phase 1 finds every
    sighting) produces no rescues, so the recalibration signal stays zero."""
    from repro.core.profiler import drift_score

    vis, gal, feats, model = _toy_world()
    q_vids, _ = make_queries(vis, 2, seed=0)
    p = SearchPolicy(scheme="rexcam", s_thresh=0.3, t_thresh=0.02, exit_t=60)
    eng = rexcam.serve(model, embed_fn=lambda x: x, policy=p)
    for i, v in enumerate(q_vids):
        eng.submit_query(i, feats[v], int(vis.cam[v]), int(vis.t_out[v]))
    _drive_world(eng, vis, gal, feats)

    assert sum(len(q.matches) for q in eng.queries.values()) > 0
    assert eng.rescue_pairs.sum() == 0
    assert (np.asarray(drift_score(model, eng.rescue_pairs)) == 0).all()


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def test_api_track_matches_direct_call(duke_sim):
    from repro.core.tracker import track_queries
    p = SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02)
    a = rexcam.track(duke_sim["model"], duke_sim["vis"], duke_sim["gal"],
                     duke_sim["feats"], duke_sim["q_vids"],
                     duke_sim["gt_vids"], p)
    b = track_queries(duke_sim["model"], duke_sim["vis"], duke_sim["gal"],
                      duke_sim["feats"], duke_sim["q_vids"],
                      duke_sim["gt_vids"], p)
    np.testing.assert_array_equal(a.cost, b.cost)
    np.testing.assert_array_equal(a.n_match, b.n_match)


def test_api_profile_equals_build_model(duke_sim):
    vis = duke_sim["vis"]
    m = rexcam.profile(vis, time_limit=1600)
    np.testing.assert_allclose(np.asarray(m.S),
                               np.asarray(duke_sim["model"].S))
