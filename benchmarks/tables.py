"""One benchmark function per paper table/figure (§8)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import scenarios
from repro.core import DetectorParams, TrackerParams, identity_detection, track_queries
from repro.core.profiler import build_model, profiling_cost


def _track(sc, p: TrackerParams):
    t0 = time.perf_counter()
    r = track_queries(sc["model"], sc["vis"], sc["gal"], sc["feats"],
                      sc["q_vids"], sc["gt_vids"], p,
                      geo_adj=sc["net"].geo_adjacent)
    wall = (time.perf_counter() - t0) * 1e6 / max(len(sc["q_vids"]), 1)
    return r, wall


def _row(name, wall_us, **derived):
    d = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                 for k, v in derived.items())
    return (name, wall_us, d)


def fig10_anoncampus():
    """Fig. 10: 5-camera AnonCampus — baseline vs ReXCam versions."""
    sc = scenarios.anoncampus()
    rows = []
    base, wall = _track(sc, TrackerParams(scheme="all"))
    rows.append(_row("fig10/anoncampus/all", wall, cost=base.total_cost,
                     recall=base.recall, precision=base.precision, savings=1.0))
    for tag, p in [
        ("S20", TrackerParams(scheme="spatial_only", s_thresh=.20)),
        ("S30-T1", TrackerParams(scheme="rexcam", s_thresh=.30, t_thresh=.01)),
        ("S30-T5", TrackerParams(scheme="rexcam", s_thresh=.30, t_thresh=.05)),
        ("S40-T10", TrackerParams(scheme="rexcam", s_thresh=.40, t_thresh=.10)),
    ]:
        r, wall = _track(sc, p)
        rows.append(_row(f"fig10/anoncampus/{tag}", wall, cost=r.total_cost,
                         recall=r.recall, precision=r.precision,
                         savings=base.total_cost / max(r.total_cost, 1),
                         delay=r.mean_delay))
    rows.append(_row("fig10/paper-ref", 0.0, savings=3.4, note="ReXCam-O 3.4x"))
    return rows


def fig11_duke():
    """Fig. 11: 8-camera Duke — the paper's headline table."""
    sc = scenarios.duke()
    rows = []
    base, wall = _track(sc, TrackerParams(scheme="all"))
    rows.append(_row("fig11/duke/all", wall, cost=base.total_cost,
                     recall=base.recall, precision=base.precision, savings=1.0))
    geo, wall = _track(sc, TrackerParams(scheme="geo"))
    rows.append(_row("fig11/duke/geo", wall, cost=geo.total_cost,
                     recall=geo.recall, precision=geo.precision,
                     savings=base.total_cost / max(geo.total_cost, 1)))
    for tag, p in [
        ("S5", TrackerParams(scheme="spatial_only", s_thresh=.05)),
        ("S5-T1", TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.01)),
        ("S5-T2", TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02)),
        ("S5-T10", TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.10)),
        ("S10-T10", TrackerParams(scheme="rexcam", s_thresh=.10, t_thresh=.10)),
    ]:
        r, wall = _track(sc, p)
        rows.append(_row(f"fig11/duke/{tag}", wall, cost=r.total_cost,
                         recall=r.recall, precision=r.precision,
                         savings=base.total_cost / max(r.total_cost, 1),
                         delay=r.mean_delay, rescued=int(r.rescued.sum())))
    rows.append(_row("fig11/paper-ref", 0.0, savings=8.3,
                     note="ReXCam-O 8.3x; precision 51->90; recall -1.6"))
    return rows


def fig12_porto():
    """Fig. 12: 130-camera Porto."""
    sc = scenarios.porto(130)
    rows = []
    base, wall = _track(sc, TrackerParams(scheme="all"))
    rows.append(_row("fig12/porto/all", wall, cost=base.total_cost,
                     recall=base.recall, precision=base.precision, savings=1.0))
    geo, wall = _track(sc, TrackerParams(scheme="geo"))
    rows.append(_row("fig12/porto/geo", wall, cost=geo.total_cost,
                     recall=geo.recall, precision=geo.precision,
                     savings=base.total_cost / max(geo.total_cost, 1)))
    for tag, p in [
        ("S1-T1", TrackerParams(scheme="rexcam", s_thresh=.01, t_thresh=.01)),
        ("S5-T2", TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02)),
        ("S12-T12", TrackerParams(scheme="rexcam", s_thresh=.12, t_thresh=.12)),
    ]:
        r, wall = _track(sc, p)
        rows.append(_row(f"fig12/porto/{tag}", wall, cost=r.total_cost,
                         recall=r.recall, precision=r.precision,
                         savings=base.total_cost / max(r.total_cost, 1),
                         delay=r.mean_delay))
    rows.append(_row("fig12/paper-ref", 0.0, savings=23.0,
                     note="ReXCam-O 23x at 130 cams"))
    return rows


def fig13_camera_scaling():
    """Fig. 13: savings grow with the number of cameras."""
    rows = []
    for n in (30, 60, 90, 130):
        sc = scenarios.porto(n)
        base, _ = _track(sc, TrackerParams(scheme="all"))
        rex, wall = _track(sc, TrackerParams(scheme="rexcam", s_thresh=.01,
                                             t_thresh=.01))
        rows.append(_row(f"fig13/porto{n}", wall,
                         savings=base.total_cost / max(rex.total_cost, 1),
                         recall=rex.recall, precision=rex.precision,
                         base_precision=base.precision))
    rows.append(_row("fig13/paper-ref", 0.0, savings=38.0,
                     note="up to 38x at 130 cams (S12-T12)"))
    return rows


def fig14_frame_skipping():
    """Fig. 14: uniform frame skipping is orthogonal to ReXCam's savings."""
    sc = scenarios.duke()
    rows = []
    for skip, tag in [(1, "none"), (3, "skip1in3"), (4, "skip1in4")]:
        # skipping 1 in k frames == the tracker steps on a k/(k-1)-decimated
        # timeline; emulate by subsampling the gallery in time.
        gal = sc["gal"].copy()
        if skip > 1:
            gal[:, ::skip] = -1          # the skipped frames are never examined
        import dataclasses

        sub = dict(sc, gal=gal)
        base, _ = _track(sub, TrackerParams(scheme="all"))
        rex, wall = _track(sub, TrackerParams(scheme="rexcam", s_thresh=.05,
                                              t_thresh=.02))
        rows.append(_row(f"fig14/{tag}", wall,
                         base_cost=base.total_cost, rex_cost=rex.total_cost,
                         savings=base.total_cost / max(rex.total_cost, 1),
                         recall=rex.recall))
    rows.append(_row("fig14/paper-ref", 0.0,
                     note="8.6x and 8.4x with skipping vs 8.3x without"))
    return rows


def fig15_replay():
    """Fig. 15: replay modes — cost vs delay tradeoffs."""
    sc = scenarios.duke()
    rows = []
    base, _ = _track(sc, TrackerParams(scheme="all"))
    for tag, p in [
        ("normal", TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02)),
        ("2xskip", TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                                 replay_skip=2)),
        ("2xff", TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                               replay_speed=2.0)),
    ]:
        r, wall = _track(sc, p)
        rows.append(_row(f"fig15/{tag}", wall, cost=r.total_cost,
                         savings=base.total_cost / max(r.total_cost, 1),
                         recall=r.recall, precision=r.precision,
                         delay=r.mean_delay))
    rows.append(_row("fig15/paper-ref", 0.0,
                     note="delay 2.6->1.8 (2xskip) / 1.3 (2xff); "
                          "savings 8.30->8.68 / 8.27"))
    return rows


def fig16_profiling():
    """Fig. 16: profiling cost (frame sampling) vs live-tracking recall."""
    sc = scenarios.duke()
    vis = sc["vis"]
    rows = []
    base, _ = _track(sc, TrackerParams(scheme="all"))
    for k in (1, 2, 4, 6, 8):
        model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out,
                            sc["net"].n_cams, time_limit=3000, sample_every=k)
        sub = dict(sc, model=model)
        rex, wall = _track(sub, TrackerParams(scheme="rexcam", s_thresh=.05,
                                              t_thresh=.02))
        cost = profiling_cost(vis.ent, vis.cam, vis.t_in, vis.t_out,
                              sample_every=k, time_limit=3000)
        rows.append(_row(f"fig16/sample{k}x", wall, profile_frames=cost,
                         recall=rex.recall, precision=rex.precision,
                         savings=base.total_cost / max(rex.total_cost, 1)))
    # break-even: profiling frames / per-query baseline-vs-rexcam saving
    full_cost = profiling_cost(vis.ent, vis.cam, vis.t_in, vis.t_out, 1, 3000)
    rex, _ = _track(sc, TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02))
    per_query_saving = (base.total_cost - rex.total_cost) / len(sc["q_vids"])
    rows.append(_row("fig16/break-even-queries", 0.0,
                     queries=float(np.ceil(full_cost / max(per_query_saving, 1))),
                     note="paper: 34 queries"))
    return rows


def fig17_identity_detection():
    """Fig. 17: identity detection (§5.4) — lost-identity scenario: the query
    enters the network at an unknown time/camera after the search starts."""
    from repro.core.detect import make_detection_queries

    sc = scenarios.duke()
    t_start = 3200
    q = make_detection_queries(sc["vis"], 40, search_start=t_start, seed=1)
    rows = []
    t0 = time.perf_counter()
    base = identity_detection(sc["model"], sc["vis"], sc["feats"], q,
                              DetectorParams(theta=0.95), baseline=True,
                              t_refs=t_start)
    wall = (time.perf_counter() - t0) * 1e6 / max(len(q), 1)
    rows.append(_row("fig17/baseline", wall, cost=base["cost"],
                     recall=base["recall"], precision=base["precision"]))
    for theta in (0.95, 0.85, 0.75):
        t0 = time.perf_counter()
        r = identity_detection(sc["model"], sc["vis"], sc["feats"], q,
                               DetectorParams(theta=theta), t_refs=t_start)
        wall = (time.perf_counter() - t0) * 1e6 / max(len(q), 1)
        rows.append(_row(f"fig17/theta{theta}", wall, cost=r["cost"],
                         savings=base["cost"] / max(r["cost"], 1),
                         recall=r["recall"], precision=r["precision"],
                         rounds=r["rounds"]))
    rows.append(_row("fig17/paper-ref", 0.0,
                     note="7.6x at theta=.95; 6.6x at .75 w/ no recall drop"))
    return rows


def sec3_potential():
    """§3: analytic potential of spatial/temporal/combined filtering."""
    sc = scenarios.duke()
    m = sc["model"]
    S = np.asarray(m.S)
    peers = (S >= 0.05).sum(1)
    rows = [
        _row("sec3/peers_ge_5pct", 0.0, mean=float(peers.mean()),
             note="paper: 1.9 of 7"),
        _row("sec3/spatial_only_potential", 0.0,
             savings=m.potential_savings(0.05, 0.0), note="paper: 3.7x"),
        _row("sec3/temporal_only_potential", 0.0,
             savings=m.potential_savings(0.0, 0.02), note="paper: 7.5x"),
        _row("sec3/combined_potential", 0.0,
             savings=m.potential_savings(0.05, 0.02), note="paper: 9.4x"),
    ]
    from repro.core.profiler import transitions_from_visits
    vis = sc["vis"]
    _, _, dt, _, _ = transitions_from_visits(vis.ent, vis.cam, vis.t_in, vis.t_out)
    rows.append(_row("sec3/travel_stats", 0.0, mean_s=float(dt.mean()),
                     std_s=float(dt.std()), note="paper: 44.2 / 10.3"))
    return rows
