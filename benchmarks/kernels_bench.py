"""Kernel micro-benchmarks.

CPU wall times are NOT TPU predictions — the interpret-mode numbers exist to
catch pathological regressions and to time the pure-jnp reference path the
CPU examples actually execute.  TPU performance is assessed structurally via
the dry-run roofline (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, iters=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    rows = []

    B, H, KV, S, hd = 1, 8, 2, 1024, 64
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    flops = 4 * B * H * S * S * hd
    us_ref = _time(lambda *a: ref.flash_attention_ref(*a), q, k, v)
    us_k = _time(lambda *a: ops.flash_attention(*a, block_q=256, block_k=256), q, k, v)
    rows.append(("kernels/flash_attention_interp", us_k,
                 f"ref_us={us_ref:.0f};flops={flops:.3g};mode=interpret"))

    T = 8192
    qd = jax.random.normal(ks[3], (4, H, hd))
    kc = jax.random.normal(ks[4], (4, KV, T, hd))
    vc = jax.random.normal(ks[5], (4, KV, T, hd))
    length = jnp.full((4,), T, jnp.int32)
    us_ref = _time(lambda *a: ref.decode_attention_ref(*a), qd, kc, vc, length)
    us_k = _time(lambda *a: ops.decode_attention(*a, block_k=1024), qd, kc, vc, length)
    rows.append(("kernels/decode_attention_interp", us_k, f"ref_us={us_ref:.0f}"))

    Q, G, D = 256, 8192, 64
    qq = jax.random.normal(ks[6], (Q, D))
    gg = jax.random.normal(ks[7], (G, D))
    us_ref = _time(lambda *a: ref.reid_topk_ref(*a, 16), qq, gg)
    us_k = _time(lambda *a: ops.reid_topk(*a, 16, block_q=128, block_g=1024), qq, gg)
    rows.append(("kernels/reid_topk_interp", us_k,
                 f"ref_us={us_ref:.0f};gallery={G}"))

    Bm_, L, Dd, N = 1, 1024, 256, 16
    u = jax.random.normal(ks[0], (Bm_, L, Dd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bm_, L, Dd))) * 0.1
    Bm = jax.random.normal(ks[2], (Bm_, L, N)) * 0.5
    Cm = jax.random.normal(ks[3], (Bm_, L, N)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (Dd, N)) * 0.3)
    us_ref = _time(lambda *a: ref.mamba_scan_ref(*a, jnp.zeros((Bm_, Dd, N)))[0],
                   u, dt, Bm, Cm, A)
    us_k = _time(lambda *a: ops.mamba_scan(*a, chunk=128, block_d=128),
                 u, dt, Bm, Cm, A)
    rows.append(("kernels/mamba_scan_interp", us_k, f"ref_us={us_ref:.0f}"))
    return rows
