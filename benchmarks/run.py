# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import drift, kernels_bench, scenarios, tables

ALL = {
    "policy_sweep": scenarios.policy_sweep,
    "serving_sweep": scenarios.serving_sweep,
    "serving_shard_sweep": scenarios.serving_shard_sweep,
    "gallery_sweep": scenarios.gallery_sweep,
    "drift_sweep": scenarios.drift_sweep,
    "transport_sweep": scenarios.transport_sweep,
    "query_churn_sweep": scenarios.query_churn_sweep,
    "tile_sweep": scenarios.tile_sweep,
    "soak_130": scenarios.soak_130,
    "sec3_potential": tables.sec3_potential,
    "fig10_anoncampus": tables.fig10_anoncampus,
    "fig11_duke": tables.fig11_duke,
    "fig12_porto": tables.fig12_porto,
    "fig13_camera_scaling": tables.fig13_camera_scaling,
    "fig14_frame_skipping": tables.fig14_frame_skipping,
    "fig15_replay": tables.fig15_replay,
    "fig16_profiling": tables.fig16_profiling,
    "fig17_identity_detection": tables.fig17_identity_detection,
    "sec6_drift": drift.run,
    "kernels": kernels_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None, choices=list(ALL))
    ap.add_argument("--bench-dir", default=None, metavar="DIR",
                    help="write machine-readable BENCH_<scenario>.json files "
                    "(admitted_steps, unique_frames, wall, p50/p99 round "
                    "latency per config) for every sweep that records them")
    args = ap.parse_args()
    names = args.only or list(ALL)

    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        scenarios.pop_bench_records(name)  # drop stale in-process records
        try:
            rows = ALL[name]()
        except Exception as e:  # noqa: BLE001 — report and continue the suite
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.1f},{derived}")
        recs = scenarios.pop_bench_records(name)
        if args.bench_dir and recs:
            os.makedirs(args.bench_dir, exist_ok=True)
            path = os.path.join(args.bench_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({"scenario": name, "records": recs}, f, indent=1)
            print(f"# {name}: {len(recs)} records -> {path}", file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
