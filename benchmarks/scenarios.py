"""Shared benchmark scenarios (built once, cached in-process and on disk).

Each scenario = (network, profile model, live visits, gallery, features,
queries) — profiling runs on a dedicated historical partition, live tracking
on held-out traffic, exactly the paper's §8.1 methodology.

``policy_sweep`` additionally exercises every admission scheme through the
``repro.api`` facade and reports compute-savings multipliers vs the
all-camera baseline (paper targets: 8.3x on Duke, 23-38x at city scale).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import api as rexcam
from repro.core import (anoncampus_like_network, build_gallery, build_model,
                        clustered_city_network, concat_visits,
                        duke_like_network, permute_network,
                        porto_like_network, simulate_network)
from repro.core.features import FeatureParams, make_features
from repro.core.simulate import restrict_network
from repro.core.tracker import make_queries


# ---------------------------------------------------------------------------
# Machine-readable benchmark records (BENCH_<scenario>.json via run.py).
# ---------------------------------------------------------------------------

#: scenario name -> list of record dicts appended by ``bench_record`` while a
#: sweep runs; ``benchmarks/run.py --bench-dir`` drains this into
#: ``BENCH_<scenario>.json`` after the sweep returns.
BENCH_RECORDS: dict = {}

#: The golden record schema: every measured BENCH row carries these, so the
#: perf trajectory (one BENCH_*.json per scenario, uploaded by CI) stays
#: joinable across scenarios and across time.  Rows that summarize OTHER
#: rows rather than a measured run (ratios, gates) opt out with
#: ``derived=True``.  ``scripts/bench_schema_check.py`` re-validates the
#: emitted JSON in CI, and ``tests/test_system.py`` audits every
#: ``bench_record`` call site against this tuple.
REQUIRED_BENCH_KEYS = ("scenario", "admitted_steps", "unique_frames",
                       "wall_s", "p50_tick_ms", "p99_tick_ms")


def bench_record(sweep: str, **fields) -> None:
    """Append one machine-readable record for ``BENCH_<sweep>.json``.
    Measured rows must carry every ``REQUIRED_BENCH_KEYS`` field; derived
    summary rows (``derived=True``) are exempt."""
    if not fields.get("derived"):
        missing = [k for k in REQUIRED_BENCH_KEYS if k not in fields]
        if missing:
            raise ValueError(
                f"bench_record({sweep!r}): measured record missing required "
                f"keys {missing} (pass derived=True for summary rows)")
    BENCH_RECORDS.setdefault(sweep, []).append(fields)


def pop_bench_records(sweep: str):
    """Drain (and clear) the records a sweep accumulated — run.py calls this
    both before a sweep (drop stale in-process state) and after (collect)."""
    return BENCH_RECORDS.pop(sweep, [])


def _tick_pcts(tick_lat):
    """(p50_ms, p99_ms) over a list of per-tick wall latencies in seconds."""
    if not tick_lat:
        return 0.0, 0.0
    p50, p99 = np.percentile(np.asarray(tick_lat) * 1e3, [50, 99])
    return float(p50), float(p99)


@functools.lru_cache(maxsize=None)
def duke(n_queries: int = 100):
    net = duke_like_network()
    vis = simulate_network(net, 2700, 5100, seed=0)   # 85 min @ 1 step/s
    gal, _ = build_gallery(vis, 24)
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                        time_limit=3000)               # profile partition
    feats, _ = make_features(vis, 2700, FeatureParams())
    q_vids, gt_vids = make_queries(vis, n_queries, seed=1)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, name="duke")


@functools.lru_cache(maxsize=None)
def anoncampus(n_queries: int = 20):
    net = anoncampus_like_network()
    vis = simulate_network(net, 700, 2100, seed=5)     # 35 min @ 1 step/s
    gal, _ = build_gallery(vis, 24)
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                        time_limit=1300)
    # indoor occlusions: noisier features (paper §8.2 recall note)
    feats, _ = make_features(vis, 700, FeatureParams(noise_sigma=0.55, seed=5))
    q_vids, gt_vids = make_queries(vis, n_queries, seed=6)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, name="anoncampus")


@functools.lru_cache(maxsize=None)
def porto(n_cams: int = 130, n_queries: int = 100):
    net = porto_like_network(130)
    cams = np.arange(n_cams)
    if n_cams < 130:
        net = restrict_network(net, cams)
    # dedicated historical partition for profiling (denser statistics)
    hist = simulate_network(net, 6000, 7200, seed=11)
    model = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, net.n_cams)
    vis = simulate_network(net, 2000, 3600, seed=2)
    gal, _ = build_gallery(vis, 16)
    # city-scale identity diversity: more lookalike groups than the campus
    # sims (keeps the baseline near the paper's ~50% precision at 130 cams)
    feats, _ = make_features(vis, 2000, FeatureParams(n_clusters=400, seed=2))
    q_vids, gt_vids = make_queries(vis, n_queries, seed=3)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, name=f"porto{n_cams}")


# ---------------------------------------------------------------------------
# policy_sweep: every admission scheme through the repro.api facade.
# ---------------------------------------------------------------------------

SWEEP_POLICIES = (
    ("all", rexcam.SearchPolicy(scheme="all")),
    ("geo", rexcam.SearchPolicy(scheme="geo")),
    ("spatial_only", rexcam.SearchPolicy(scheme="spatial_only", s_thresh=.05)),
    ("rexcam", rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02)),
)


def policy_sweep(scenarios=("duke", "porto130")):
    """(name, us_per_call, derived) rows: per scenario, each scheme's cost,
    recall/precision, and savings multiplier vs the all-camera baseline
    (paper Table targets: 8.3x Duke spatio-temporal, 23-38x at 130 cams)."""
    builders = {"duke": lambda: duke(60), "anoncampus": lambda: anoncampus(20),
                "porto130": lambda: porto(130, 60)}
    rows = []
    for sc_name in scenarios:
        sc = builders[sc_name]()
        base_cost = None
        for pname, policy in SWEEP_POLICIES:
            t0 = time.perf_counter()
            r = rexcam.track(sc["model"], sc["vis"], sc["gal"], sc["feats"],
                             sc["q_vids"], sc["gt_vids"], policy,
                             geo_adj=sc["net"].geo_adjacent)
            # per-query us, matching the other benchmark tables' convention
            us = (time.perf_counter() - t0) * 1e6 / max(len(sc["q_vids"]), 1)
            if pname == "all":
                base_cost = r.total_cost
            savings = base_cost / max(r.total_cost, 1.0)
            rows.append((f"policy_sweep/{sc['name']}/{pname}", us,
                         f"savings={savings:.1f}x recall={r.recall:.2f} "
                         f"precision={r.precision:.2f} "
                         f"rescued={int(r.rescued.sum())}"))
    return rows


# ---------------------------------------------------------------------------
# serving_sweep: the live engine's cost accounting, per scheme.
# ---------------------------------------------------------------------------

def _drive_serving(sc, policy, n_queries, steps, shards=None,
                   gallery="auto", transport=None, prefetch=False,
                   guard_steady_after=None, tile_grid=0, model=None,
                   topk_rerank=False, prime_gal=0):
    """The one engine-driving loop every serving benchmark shares: build the
    engine (fleet when ``shards``), submit the scenario's queries, replay the
    live stream tick by tick.  Returns (engine, matches, wall seconds
    including engine construction and jit warmup, per-tick wall latencies).

    ``transport=``/``prefetch=`` pass straight through to ``rexcam.serve`` —
    the transport_sweep drives the same loop with a ``FakeRpcTransport`` so
    its walls are comparable against every other serving row.

    ``tile_grid=T > 0`` serves through the sub-frame spatial admission plane
    (per-detection tile labels from the scenario's ground-truth positions
    ride along with every ingest); ``model=`` overrides the scenario's
    profile — tile_sweep passes a tile-carrying re-profile of the same
    visits.  ``topk_rerank=`` turns on §5.2 confidence re-ranking.

    ``guard_steady_after=N`` arms a ``RecompileGuard`` over every registered
    jit entry (plus the fleet's shard_map jits) once tick N is reached: the
    remaining ticks are the benchmark's steady state, and a compile-cache
    miss there (shape churn, a kwarg leaking out of the statics) raises
    instead of silently poisoning the reported walls."""
    from repro.analysis import RecompileGuard

    vis, gal, feats, net = sc["vis"], sc["gal"], sc["feats"], sc["net"]
    q_vids = sc["q_vids"][:n_queries]
    vis_tiles = None
    if tile_grid > 0:
        from repro.core.simulate import tile_index
        vis_tiles = tile_index(vis.tile_xy, tile_grid)
    wall0 = time.perf_counter()
    eng = rexcam.serve(sc["model"] if model is None else model,
                       embed_fn=lambda x: x, policy=policy,
                       geo_adj=net.geo_adjacent, shards=shards,
                       gallery=gallery, transport=transport,
                       prefetch=prefetch, tile_grid=tile_grid,
                       topk_rerank=topk_rerank)
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    for i, q in enumerate(q_vids):
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    # pre-size the padded batch: round cohorts form lazily (a 3-query
    # cohort may first appear hundreds of ticks in), and every pow2 growth
    # mints a jit signature — priming moves them all into warmup so the
    # RecompileGuard-ed steady half compiles nothing
    eng.prime_batch(len(q_vids))
    if prime_gal:
        # the gallery side has the same lazy-growth problem: a late phase-2
        # rescue can admit the largest round gallery yet — callers that
        # guard their steady state pass the high-water mark of an unguarded
        # warmup drive so the rank signature is minted once, up front
        eng.prime_gallery(prime_gal)
    matches = 0
    tick_lat = []
    guard = None
    for step_i, t in enumerate(range(t0, min(t0 + steps, vis.horizon))):
        if guard_steady_after is not None and step_i == guard_steady_after:
            # each entry may mint at most ONE more signature after warmup
            # (a genuinely new shape class, e.g. the round gallery growing
            # past its high-water mark); per-tick churn trips immediately
            guard = RecompileGuard.for_engine(
                eng, max_new=1, label=f"steady after tick {step_i}")
            guard.__enter__()
        frames, tiles = {}, {}
        for c in range(net.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
                if vis_tiles is not None:
                    tiles[c] = vis_tiles[vids]
        if tile_grid > 0:
            eng.ingest(frames, tiles)
        else:
            eng.ingest(frames)
        tk0 = time.perf_counter()
        matches += eng.tick()["matches"]
        tick_lat.append(time.perf_counter() - tk0)
    if guard is not None:
        guard.__exit__(None, None, None)
    return eng, matches, time.perf_counter() - wall0, tick_lat


def _match_delay(eng) -> float:
    """Mean ticks from submit to the first confirmed match (the Fig. 15
    detection-delay metric) over the queries that ever matched; -1 when
    none did."""
    d = [q.first_match_t - q.submit_t for q in eng.queries.values()
         if q.first_match_t >= 0]
    return float(np.mean(d)) if d else -1.0


#: §5.3 replay catch-up modes for the Fig. 15-style serving rows: real-time
#: replay, fast-forward (parallelism — extra content rounds per wall tick)
#: and frame-skip (sample every k-th content frame while behind).
REPLAY_MODES = (
    ("base", {}),
    ("ff", dict(replay_speed=4.0)),
    ("skip", dict(replay_skip=4)),
)


def serving_sweep(scenarios=("duke",), n_queries=16, steps=400):
    """Engine-plane sweep: drive the live ``ServingEngine`` per scheme over
    real ingest and report the two cost conventions separately —
    ``admitted_steps`` (per-query camera-steps, directly comparable with the
    tracker's cost and ``policy_sweep``'s savings multipliers) and
    ``unique_frames`` (deduplicated inference load), plus the multipliers
    the serving plane adds on top: cross-query dedup and the FrameStore
    embedding-cache hit rate on replay re-reads.

    A second block of rows replays Fig. 15 ON THE SERVING PLANE: the rexcam
    scheme under each §5.3 replay catch-up mode (real-time, fast-forward,
    frame-skip), reporting cost (admitted/content/replay steps) against the
    detection delay (mean ticks from submit to first confirmed match) —
    one ``BENCH_serving_sweep.json`` record per replay mode."""
    builders = {"duke": lambda: duke(60)}
    rows = []
    for sc_name in scenarios:
        sc = builders[sc_name]()
        n_q = min(n_queries, len(sc["q_vids"]))
        base = None
        for pname, policy in SWEEP_POLICIES:
            eng, matches, wall, lat = _drive_serving(
                sc, policy, n_q, steps, guard_steady_after=steps // 2)
            us = wall * 1e6 / max(n_q, 1)
            if pname == "all":
                base = eng.admitted_steps
            savings = base / max(eng.admitted_steps, 1)
            dedup = eng.admitted_steps / max(eng.unique_frames, 1)
            # hit rate over replay re-reads only — live first-embeds can
            # never be cache hits and would just dilute the number
            hot = eng.cache_hits / max(eng.cache_hits + eng.replay_embeds, 1)
            p50, p99 = _tick_pcts(lat)
            bench_record("serving_sweep", scenario=sc["name"], policy=pname,
                         admitted_steps=int(eng.admitted_steps),
                         unique_frames=int(eng.unique_frames),
                         wall_s=round(wall, 4), p50_tick_ms=round(p50, 3),
                         p99_tick_ms=round(p99, 3), matches=int(matches))
            rows.append((f"serving_sweep/{sc['name']}/{pname}", us,
                         f"savings={savings:.1f}x "
                         f"admitted_steps={eng.admitted_steps} "
                         f"unique_frames={eng.unique_frames} "
                         f"dedup={dedup:.1f}x replay_cache_hot={hot:.2f} "
                         f"matches={matches}"))
        # Fig. 15 on the serving plane: cost vs detection delay per §5.3
        # replay mode (ff buys delay with extra content rounds per tick,
        # skip buys cost by sampling every k-th content frame while behind)
        for mode, knobs in REPLAY_MODES:
            policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05,
                                         t_thresh=.02, **knobs)
            eng, matches, wall, lat = _drive_serving(
                sc, policy, n_q, steps, guard_steady_after=steps // 2)
            delay = _match_delay(eng)
            p50, p99 = _tick_pcts(lat)
            bench_record("serving_sweep", scenario=sc["name"],
                         policy="rexcam", replay_mode=mode,
                         replay_speed=float(policy.replay_speed),
                         replay_skip=int(policy.replay_skip),
                         admitted_steps=int(eng.admitted_steps),
                         unique_frames=int(eng.unique_frames),
                         content_steps=int(eng.content_steps),
                         replay_steps=int(eng.replay_steps),
                         skipped_steps=int(eng.skipped_steps),
                         detection_delay_ticks=round(delay, 2),
                         matches=int(matches), wall_s=round(wall, 4),
                         p50_tick_ms=round(p50, 3),
                         p99_tick_ms=round(p99, 3))
            rows.append((f"serving_sweep/{sc['name']}/replay_{mode}",
                         wall * 1e6 / max(n_q, 1),
                         f"delay={delay:.1f}ticks "
                         f"admitted_steps={eng.admitted_steps} "
                         f"content_steps={eng.content_steps} "
                         f"replay_steps={eng.replay_steps} "
                         f"skipped={eng.skipped_steps} matches={matches}"))
    return rows


# ---------------------------------------------------------------------------
# tile_sweep: sub-frame spatial admission — tile-granular pixel load vs the
# camera-granular baseline, at equal recall.
# ---------------------------------------------------------------------------

def tile_sweep(n_queries=16, steps=400, tile_grid=8, tile_keep=1.0):
    """The sub-frame spatial admission tentpole, measured and asserted on
    duke:

    * DIFFERENTIAL — serving with ``tile_grid=T`` over the scenario's
      tile-less profile (the engine synthesizes the all-tiles-admitted
      tensor) must reproduce the camera-granular baseline exactly: same
      admitted_steps / unique_frames / matches, with
      ``admitted_tiles == T*T * admitted_steps`` (the tile plane is a pure
      refinement — asserted end to end, mirroring the fleet differential);
    * LEARNED MASKS — re-profiling the same visits with
      ``profile(..., tile_grid=T)`` learns per (src, dst) camera-pair
      entry-region masks; serving through them must cut the admitted
      pixel-load proxy (tiles actually scored, vs the camera-granular T*T
      ceiling at the same admissions) by >= 2x at recall no worse than the
      baseline's — both ASSERTED, the acceptance gate the CI smoke greps.

    The pixel-load convention: a camera-granular admitted step decodes/
    scores all T*T tiles of the frame; a tile-granular step touches only
    the fused cells the model admits.  ``unique_tiles`` is the same under
    the deduplicated convention (per-key tile unions vs T*T per unique
    frame)."""
    sc = duke(60)
    vis = sc["vis"]
    n_q = min(n_queries, len(sc["q_vids"]))
    q_vids, gt_vids = sc["q_vids"][:n_q], sc["gt_vids"][:n_q]
    T, TT = tile_grid, tile_grid * tile_grid
    policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02)
    rows = []

    # unguarded warmup drive: learns the round-gallery row high-water mark
    # (gallery shapes grow lazily — the largest round gallery can first
    # appear deep in the run) so the three guarded drives below can prime
    # both sides of every jit signature up front and compile nothing in
    # their steady halves
    warm, _, _, _ = _drive_serving(sc, policy, n_q, steps)
    gal_rows = warm.padded_gallery_rows

    # camera-granular baseline
    base, m_base, wall_b, lat_b = _drive_serving(
        sc, policy, n_q, steps, guard_steady_after=steps // 2,
        prime_gal=gal_rows)
    recall_b = _serving_recall(base, vis, q_vids, gt_vids)
    p50_b, p99_b = _tick_pcts(lat_b)
    bench_record("tile_sweep", scenario=sc["name"], config="camera",
                 tile_grid=0, admitted_steps=int(base.admitted_steps),
                 unique_frames=int(base.unique_frames),
                 admitted_tiles=TT * int(base.admitted_steps),
                 recall=round(recall_b, 4), matches=int(m_base),
                 wall_s=round(wall_b, 4), p50_tick_ms=round(p50_b, 3),
                 p99_tick_ms=round(p99_b, 3))
    rows.append((f"tile_sweep/{sc['name']}/camera",
                 wall_b * 1e6 / max(n_q, 1),
                 f"recall={recall_b:.2f} "
                 f"admitted_steps={base.admitted_steps} "
                 f"pixel_load={TT * base.admitted_steps}tiles "
                 f"matches={m_base}"))

    # all-tiles-admitted differential: the tile execution path over the
    # SAME tile-less model must change nothing but the counters' units
    alladm, m_all, wall_a, lat_a = _drive_serving(
        sc, policy, n_q, steps, tile_grid=T, guard_steady_after=steps // 2,
        prime_gal=gal_rows)
    assert alladm.admitted_steps == base.admitted_steps, \
        "tile path changed admitted_steps under all-admitted tiles"
    assert alladm.unique_frames == base.unique_frames, \
        "tile path changed unique_frames under all-admitted tiles"
    assert m_all == m_base, "tile path changed match outcomes"
    assert alladm.admitted_tiles == TT * alladm.admitted_steps
    assert alladm.unique_tiles == TT * alladm.unique_frames
    p50_a, p99_a = _tick_pcts(lat_a)
    bench_record("tile_sweep", scenario=sc["name"], config="all_admitted",
                 tile_grid=T, admitted_steps=int(alladm.admitted_steps),
                 unique_frames=int(alladm.unique_frames),
                 admitted_tiles=int(alladm.admitted_tiles),
                 unique_tiles=int(alladm.unique_tiles),
                 recall=round(recall_b, 4), matches=int(m_all),
                 wall_s=round(wall_a, 4), p50_tick_ms=round(p50_a, 3),
                 p99_tick_ms=round(p99_a, 3))
    rows.append((f"tile_sweep/{sc['name']}/all_admitted",
                 wall_a * 1e6 / max(n_q, 1),
                 f"differential=ok admitted_tiles={alladm.admitted_tiles} "
                 f"(=TT*admitted_steps) matches={m_all} "
                 f"wall={wall_a:.2f}s vs camera {wall_b:.2f}s"))

    # learned entry-region masks, profiled on the scenario's own profile
    # partition (same time_limit as the camera model)
    tile_model = rexcam.profile(vis, time_limit=3000, tile_grid=T,
                                tile_keep=tile_keep)
    learned, m_t, wall_t, lat_t = _drive_serving(
        sc, policy, n_q, steps, tile_grid=T, model=tile_model,
        guard_steady_after=steps // 2, prime_gal=gal_rows)
    recall_t = _serving_recall(learned, vis, q_vids, gt_vids)
    pixel_base = TT * base.admitted_steps
    reduction = pixel_base / max(learned.admitted_tiles, 1)
    dedup_red = (TT * learned.unique_frames) / max(learned.unique_tiles, 1)
    p50_t, p99_t = _tick_pcts(lat_t)
    bench_record("tile_sweep", scenario=sc["name"], config="learned",
                 tile_grid=T, tile_keep=tile_keep,
                 admitted_steps=int(learned.admitted_steps),
                 unique_frames=int(learned.unique_frames),
                 admitted_tiles=int(learned.admitted_tiles),
                 unique_tiles=int(learned.unique_tiles),
                 pixel_reduction=round(reduction, 2),
                 recall=round(recall_t, 4), matches=int(m_t),
                 wall_s=round(wall_t, 4), p50_tick_ms=round(p50_t, 3),
                 p99_tick_ms=round(p99_t, 3))
    rows.append((f"tile_sweep/{sc['name']}/learned",
                 wall_t * 1e6 / max(n_q, 1),
                 f"pixel_reduction={reduction:.1f}x "
                 f"admitted_tiles={learned.admitted_tiles} "
                 f"of {pixel_base} camera-granular "
                 f"dedup_reduction={dedup_red:.1f}x "
                 f"recall={recall_t:.2f} (camera {recall_b:.2f}) "
                 f"matches={m_t} wall={wall_t:.2f}s"))

    # --- the acceptance asserts ----------------------------------------
    assert reduction >= 2.0, \
        f"tile_sweep: learned masks cut pixel load only {reduction:.2f}x " \
        f"({learned.admitted_tiles} of {pixel_base} tiles) — need >= 2x"
    assert recall_t >= recall_b, \
        f"tile_sweep: tile recall {recall_t:.3f} dropped below the " \
        f"camera-granular baseline's {recall_b:.3f}"
    rows.append((f"tile_sweep/{sc['name']}/acceptance", 0.0,
                 f"tile_gate=ok reduction={reduction:.1f}x>=2x "
                 f"recall_delta={recall_t - recall_b:+.3f}"))
    return rows


# ---------------------------------------------------------------------------
# serving_shard_sweep: the fleet vs one engine, per shard count.
# ---------------------------------------------------------------------------

def serving_shard_sweep(scenarios=("duke",), n_queries=16, steps=300,
                        shard_counts=(1, 2, 4, 8)):
    """Shard the live query axis over {1, 2, 4, 8} devices and report, per
    shard count: wall-clock speedup vs the single-process engine, the fleet
    totals (which must EQUAL the single engine's — the differential-harness
    invariant, asserted here too), and the per-shard ``admitted_steps`` /
    ``unique_frames`` split (each worker's shard-local demand).

    Shard counts above the visible device count are reported as skipped —
    run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (and
    ``JAX_PLATFORMS=cpu``) to sweep the full fleet on one host."""
    import jax

    builders = {"duke": lambda: duke(60)}
    rows = []
    n_dev = len(jax.devices())
    for sc_name in scenarios:
        sc = builders[sc_name]()
        n_q = min(n_queries, len(sc["q_vids"]))
        policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05,
                                     t_thresh=.02)
        base_eng, _, base_wall, _ = _drive_serving(sc, policy, n_q, steps)
        for S in shard_counts:
            if S > n_dev:
                rows.append((f"serving_shard_sweep/{sc['name']}/shards{S}",
                             0.0, f"skipped: {n_dev} devices visible "
                             f"(set xla_force_host_platform_device_count)"))
                continue
            eng, _, wall, _ = _drive_serving(sc, policy, n_q, steps, shards=S)
            assert eng.admitted_steps == base_eng.admitted_steps, \
                "fleet diverged from the single engine (admitted_steps)"
            assert eng.unique_frames == base_eng.unique_frames, \
                "fleet diverged from the single engine (unique_frames)"
            rep = eng.shard_report()
            per_adm = "/".join(str(r["admitted_steps"]) for r in rep)
            per_uni = "/".join(str(r["unique_frames"]) for r in rep)
            rows.append((f"serving_shard_sweep/{sc['name']}/shards{S}",
                         wall * 1e6 / max(n_q, 1),
                         f"speedup={base_wall / max(wall, 1e-9):.2f}x "
                         f"wall={wall:.2f}s "
                         f"admitted_steps={eng.admitted_steps} "
                         f"unique_frames={eng.unique_frames} "
                         f"per_shard_admitted={per_adm} "
                         f"per_shard_unique={per_uni}"))
    return rows


# ---------------------------------------------------------------------------
# drift_sweep: the §6 degradation argument on the SERVING plane — inject a
# mid-run traffic-pattern shift and compare frozen vs recalibrating engines.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def drifted_duke(n_queries: int = 32, t_shift: int = 400,
                 post_horizon: int = 1800):
    """Duke-like world whose live stream shifts topology at ``t_shift``:
    cameras are re-permuted (a derangement — every pair the frozen profile
    trusts becomes wrong), while the model stays profiled on dedicated
    PRE-shift history.  Queries are drawn from the post-shift traffic, so
    every reported recall is "after the injected shift"."""
    net = duke_like_network()
    shifted = permute_network(net, np.roll(np.arange(net.n_cams), 3))
    hist = simulate_network(net, 2000, 4000, seed=31)
    model = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, net.n_cams)
    vis_a = simulate_network(net, 300, t_shift, seed=32)
    vis_b = simulate_network(shifted, 800, post_horizon, seed=33)
    vis = concat_visits(vis_a, vis_b, t_shift)
    gal, _ = build_gallery(vis, 24)
    feats, _ = make_features(vis, int(vis.ent.max()) + 1,
                             FeatureParams(seed=33))
    q_b, gt_b = make_queries(vis_b, n_queries, seed=34)
    q_vids = q_b + len(vis_a)
    gt_vids = np.where(gt_b >= 0, gt_b + len(vis_a), gt_b)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, t_shift=t_shift,
                name="duke-drift")


def _serving_recall(eng, vis, q_vids, gt_vids) -> float:
    """Tracker-comparable recall for the live engine: a ground-truth visit
    counts as retrieved when some confirmed match (cam, frame) lands inside
    it."""
    hits = total = 0
    for i in range(len(q_vids)):
        gts = gt_vids[i][gt_vids[i] >= 0]
        total += len(gts)
        ms = eng.queries[i].matches
        hits += sum(any(c == vis.cam[v] and vis.t_in[v] <= f <= vis.t_out[v]
                        for c, f in ms) for v in gts)
    return hits / max(total, 1)


def drift_sweep(n_queries: int = 32, shards: int = 8):
    """Paper §6 end-to-end ON THE SERVING PLANE: a re-permuted camera
    topology mid-run makes the frozen profile prune exactly the frames the
    traffic now uses; with ``recalibrate=`` on, the engine's live rescue
    matrix trips the drift trigger, a model re-profiled from the recent
    window hot-swaps in (epoch-bumped, queries in flight), and post-shift
    recall recovers — at LOWER admission cost, because the fresh model also
    prunes correctly again.  Reported rows: frozen baseline, recalibrating
    single engine, recalibrating ``shards``-way fleet (identical totals —
    the swap is atomic across the mesh).

    The recovery is asserted, not just reported: recalibrated recall must
    be strictly above the frozen-model row's (the CI drift smoke runs this).
    """
    import jax

    sc = drifted_duke(n_queries)
    vis, gal, feats, net = sc["vis"], sc["gal"], sc["feats"], sc["net"]
    q_vids, gt_vids = sc["q_vids"], sc["gt_vids"]
    policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02)
    # trigger tuned to the duke profile's density: the hot drifted pairs
    # carry ~10-30 historical transitions, so a handful of rescues there
    # scores ~0.1-0.15 (see RecalibrationPolicy.drift_threshold's scale note)
    recal = rexcam.RecalibrationPolicy(drift_threshold=.06, min_rescues=8,
                                       cooldown=300, poll_every=20,
                                       window=600)

    def drive(recalibrate, n_shards=None):
        wall0 = time.perf_counter()
        eng = rexcam.serve(sc["model"], embed_fn=lambda x: x, policy=policy,
                           geo_adj=net.geo_adjacent, shards=n_shards,
                           recalibrate=recalibrate,
                           visit_source=rexcam.visits_window_source(vis)
                           if recalibrate is not None else None)
        t0 = int(vis.t_out[q_vids].min())
        eng.t = t0
        for i, q in enumerate(q_vids):
            eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
        tick_lat = []
        for t in range(t0, vis.horizon):
            frames = {}
            for c in range(net.n_cams):
                vids = gal[c, t][gal[c, t] >= 0]
                if len(vids):
                    frames[c] = feats[vids]
            eng.ingest(frames)
            tk0 = time.perf_counter()
            eng.tick()
            tick_lat.append(time.perf_counter() - tk0)
        return eng, time.perf_counter() - wall0, tick_lat

    def record(config, eng, wall, tick_lat, recall, **extra):
        p50, p99 = _tick_pcts(tick_lat)
        bench_record("drift_sweep", scenario=sc["name"], config=config,
                     admitted_steps=int(eng.admitted_steps),
                     unique_frames=int(eng.unique_frames),
                     wall_s=round(wall, 4), p50_tick_ms=round(p50, 3),
                     p99_tick_ms=round(p99, 3), recall=round(recall, 4),
                     epoch=int(eng.model_epoch), **extra)

    rows = []
    frozen, wall_f, lat_f = drive(None)
    r_frozen = _serving_recall(frozen, vis, q_vids, gt_vids)
    record("frozen", frozen, wall_f, lat_f, r_frozen)
    rows.append((f"drift_sweep/{sc['name']}/frozen",
                 wall_f * 1e6 / max(len(q_vids), 1),
                 f"recall={r_frozen:.2f} admitted_steps={frozen.admitted_steps} "
                 f"rescues={int(frozen.rescue_pairs.sum())} epoch=0 "
                 f"note=stale model degrades silently (no re-profiling)"))

    fresh, wall_r, lat_r = drive(recal)
    r_fresh = _serving_recall(fresh, vis, q_vids, gt_vids)
    record("recalibrated", fresh, wall_r, lat_r, r_fresh,
           swaps=len(fresh.model_swaps))
    ev = fresh.recal.events
    swaps = ";".join(f"t={e['t']}:epoch{e['epoch']}(score={e['score']:.2f})"
                     for e in ev)
    rows.append((f"drift_sweep/{sc['name']}/recalibrated",
                 wall_r * 1e6 / max(len(q_vids), 1),
                 f"recall={r_fresh:.2f} admitted_steps={fresh.admitted_steps} "
                 f"epoch={fresh.model_epoch} swaps=[{swaps}] "
                 f"note=rescue spike -> re-profile -> hot-swap restores the "
                 f"operating point"))
    assert ev, "drift_sweep: the injected shift never tripped the trigger"
    assert r_fresh > r_frozen, \
        f"drift_sweep: recalibrated recall {r_fresh:.3f} must beat the " \
        f"frozen model's {r_frozen:.3f} after the injected shift"

    if shards <= len(jax.devices()):
        fleet, wall_s, lat_s = drive(recal, n_shards=shards)
        r_fleet = _serving_recall(fleet, vis, q_vids, gt_vids)
        assert fleet.admitted_steps == fresh.admitted_steps, \
            "recalibrating fleet diverged from the single engine"
        assert fleet.model_swaps == fresh.model_swaps, \
            "fleet model swaps did not land on the single engine's ticks"
        assert r_fleet == r_fresh
        record(f"recalibrated_shards{shards}", fleet, wall_s, lat_s, r_fleet,
               swaps=len(fleet.model_swaps))
        rows.append((f"drift_sweep/{sc['name']}/recalibrated_shards{shards}",
                     wall_s * 1e6 / max(len(q_vids), 1),
                     f"recall={r_fleet:.2f} "
                     f"admitted_steps={fleet.admitted_steps} "
                     f"epoch={fleet.model_epoch} "
                     f"note=swap atomic across the mesh (same ticks as the "
                     f"single engine)"))
    else:
        rows.append((f"drift_sweep/{sc['name']}/recalibrated_shards{shards}",
                     0.0, f"skipped: {len(jax.devices())} devices visible "
                     f"(set xla_force_host_platform_device_count)"))
    return rows


# ---------------------------------------------------------------------------
# gallery_sweep: one fleet-wide embedding plane vs the replicated baseline.
# ---------------------------------------------------------------------------

def gallery_sweep(scenarios=("duke",), n_queries=16, steps=300, shards=4):
    """The gallery plane's win, quantified: drive the fleet with the
    fleet-shared ``ShardedGalleryStore`` and with the replicated-baseline
    ``LocalGalleryStore`` and report, per mode:

    * embed-call reduction — fleet-global embed calls (``frames_processed``)
      vs what a replicated per-worker cache would embed (the sum of each
      shard's shard-LOCAL deduplicated demand, ``unique_frames`` in
      ``shard_report()``),
    * per-worker cache memory — each owner's resident blocks/bytes under
      the sharded store vs the whole cache replicated onto every worker.

    Both modes must stay trace-identical to the single engine (asserted via
    the fleet totals).  Needs ``shards`` visible devices — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on a CPU host."""
    import jax

    builders = {"duke": lambda: duke(60)}
    rows = []
    n_dev = len(jax.devices())
    for sc_name in scenarios:
        if shards > n_dev:
            rows.append((f"gallery_sweep/{sc_name}", 0.0,
                         f"skipped: {n_dev} devices visible "
                         f"(set xla_force_host_platform_device_count)"))
            continue
        sc = builders[sc_name]()
        n_q = min(n_queries, len(sc["q_vids"]))
        policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05,
                                     t_thresh=.02)
        single, _, _, _ = _drive_serving(sc, policy, n_q, steps)
        for mode in ("local", "sharded"):
            eng, _, wall, lat = _drive_serving(sc, policy, n_q, steps,
                                               shards=shards, gallery=mode)
            assert eng.unique_frames == single.unique_frames, \
                f"gallery={mode} fleet diverged from the single engine"
            assert eng.frames_processed == single.frames_processed, \
                f"gallery={mode} fleet re-embedded (no longer one plane)"
            rep = eng.shard_report()
            replicated_embeds = sum(r["unique_frames"] for r in rep)
            reduction = replicated_embeds / max(eng.frames_processed, 1)
            g = eng.gallery_report()
            if mode == "sharded":
                per_w = g["per_worker"]
                mem = "/".join(f"{per_w[r['worker']]['bytes']}" for r in rep)
                peak = max(v["bytes"] for v in per_w.values())
            else:
                # replicated baseline: every worker would hold the full cache
                mem = "/".join(str(g["bytes"]) for _ in rep)
                peak = g["bytes"]
            p50, p99 = _tick_pcts(lat)
            bench_record("gallery_sweep", scenario=sc["name"], gallery=mode,
                         shards=shards,
                         admitted_steps=int(eng.admitted_steps),
                         unique_frames=int(eng.unique_frames),
                         wall_s=round(wall, 4), p50_tick_ms=round(p50, 3),
                         p99_tick_ms=round(p99, 3),
                         embed_calls=int(eng.frames_processed),
                         cache_hits=int(eng.cache_hits),
                         peak_worker_bytes=int(peak))
            rows.append((f"gallery_sweep/{sc['name']}/{mode}",
                         wall * 1e6 / max(n_q, 1),
                         f"embed_calls={eng.frames_processed} "
                         f"replicated_demand={replicated_embeds} "
                         f"embed_reduction={reduction:.1f}x "
                         f"cache_hits={eng.cache_hits} "
                         f"per_worker_bytes={mem} peak_worker_bytes={peak}"))
    return rows


# ---------------------------------------------------------------------------
# transport_sweep: latency hiding — speculative prefetch vs blocking fetches.
# ---------------------------------------------------------------------------

def transport_sweep(scenarios=("duke",), n_queries=16, steps=600, shards=4,
                    rtt_scales=(1, 4, 8)):
    """The transport plane's wall-clock argument, measured and asserted:
    drive the fleet through a real-clock ``FakeRpcTransport`` whose injected
    RTT is pegged to the measured p50 round latency ("comparable to one
    ranking pass"), and show

    * the BLOCKING fetch path degrades ~linearly in injected RTT — every
      owner-shard cache hit stalls the round for a full round trip, so the
      extra wall across ``rtt_scales`` tracks ``cache_hits x RTT`` (the
      slope between the smallest and largest scale is asserted), while
    * the PREFETCHED path (double-buffered speculative fetch issued at the
      end of the previous round) hides the latency behind compute: at
      RTT = one ranking pass its wall must land within 25% of the
      zero-latency baseline (asserted), with misspeculation exactly
      accounted (``prefetch_wasted``).

    Every run must stay trace-identical — admitted_steps/unique_frames are
    asserted EQUAL across the baseline, every blocking RTT and the
    prefetched run (transport moves WHEN blocks arrive, never WHAT is
    ranked).  Uses ``steps=600`` so the replay phase re-reads enough
    owner-shard blocks (~130 remote fetches) for the walls to separate.
    Needs ``shards`` visible devices (xla_force_host_platform_device_count).
    """
    import jax

    builders = {"duke": lambda: duke(60)}
    rows = []
    n_dev = len(jax.devices())
    for sc_name in scenarios:
        if shards > n_dev:
            rows.append((f"transport_sweep/{sc_name}", 0.0,
                         f"skipped: {n_dev} devices visible "
                         f"(set xla_force_host_platform_device_count)"))
            continue
        sc = builders[sc_name]()
        n_q = min(n_queries, len(sc["q_vids"]))
        policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05,
                                     t_thresh=.02)
        # warmup run absorbs jit compilation so the walls below compare
        # injected latency, not tracing
        _drive_serving(sc, policy, n_q, min(steps, 120), shards=shards)

        base, _, wall0, lat0 = _drive_serving(sc, policy, n_q, steps,
                                              shards=shards,
                                              guard_steady_after=steps // 2)
        hits = base.cache_hits
        p50_0, p99_0 = _tick_pcts(lat0)
        # "RTT comparable to one ranking pass": the measured p50 tick
        rtt = max(0.002, p50_0 / 1e3)
        rows.append((f"transport_sweep/{sc['name']}/baseline",
                     wall0 * 1e6 / max(n_q, 1),
                     f"wall={wall0:.2f}s cache_hits={hits} "
                     f"p50_tick={p50_0:.1f}ms rtt_unit={rtt * 1e3:.1f}ms"))
        bench_record("transport_sweep", scenario=sc["name"],
                     config="baseline", rtt_ms=0.0,
                     admitted_steps=int(base.admitted_steps),
                     unique_frames=int(base.unique_frames),
                     wall_s=round(wall0, 4), p50_tick_ms=round(p50_0, 3),
                     p99_tick_ms=round(p99_0, 3), cache_hits=int(hits))

        def run(config, transport, prefetch, rtt_s):
            eng, _, wall, lat = _drive_serving(sc, policy, n_q, steps,
                                               shards=shards,
                                               transport=transport,
                                               prefetch=prefetch,
                                               guard_steady_after=steps // 2)
            assert eng.admitted_steps == base.admitted_steps, \
                f"transport config {config} changed admitted_steps"
            assert eng.unique_frames == base.unique_frames, \
                f"transport config {config} changed unique_frames"
            c = eng.gallery.counters()
            p50, p99 = _tick_pcts(lat)
            bench_record("transport_sweep", scenario=sc["name"],
                         config=config, rtt_ms=round(rtt_s * 1e3, 3),
                         admitted_steps=int(eng.admitted_steps),
                         unique_frames=int(eng.unique_frames),
                         wall_s=round(wall, 4), p50_tick_ms=round(p50, 3),
                         p99_tick_ms=round(p99, 3),
                         remote_fetches=int(c["remote_fetches"]),
                         prefetch_hits=int(c["prefetch_hits"]),
                         prefetch_wasted=int(c["prefetch_wasted"]),
                         retries=int(c["retries"]),
                         timeouts=int(c["timeouts"]))
            return eng, wall, c, p99

        # zero-latency control for the prefetched path: same speculation
        # machinery through the in-proc transport, no injected RTT — the
        # 25% bound below isolates the *latency* cost, not the (small)
        # cost of speculating itself
        _, wall_p0, _, _ = run("prefetch_rtt0", rexcam.InProcTransport(),
                               True, 0.0)

        walls_b = {}
        for s in rtt_scales:
            lat_s = rtt * s
            tr = rexcam.FakeRpcTransport(
                default=rexcam.FaultProfile(latency=lat_s),
                timeout=4 * lat_s + 1.0)
            _, wall_b, cb, p99_b = run(f"blocking_rtt{s}x", tr, False, lat_s)
            walls_b[s] = wall_b
            rows.append((f"transport_sweep/{sc['name']}/blocking_rtt{s}x",
                         wall_b * 1e6 / max(n_q, 1),
                         f"wall={wall_b:.2f}s rtt={lat_s * 1e3:.1f}ms "
                         f"extra={wall_b - wall0:+.2f}s "
                         f"stall_floor={cb['remote_fetches'] * lat_s:.2f}s "
                         f"remote_fetches={cb['remote_fetches']} "
                         f"p99_tick={p99_b:.1f}ms"))

        tr = rexcam.FakeRpcTransport(
            default=rexcam.FaultProfile(latency=rtt), timeout=4 * rtt + 1.0)
        _, wall_p, cp, p99_p = run("prefetch_rtt1x", tr, True, rtt)
        hidden = walls_b[min(rtt_scales)] - wall_p
        rows.append((f"transport_sweep/{sc['name']}/prefetch_rtt1x",
                     wall_p * 1e6 / max(n_q, 1),
                     f"wall={wall_p:.2f}s rtt={rtt * 1e3:.1f}ms "
                     f"vs_blocking={hidden:+.2f}s "
                     f"prefetch_hits={cp['prefetch_hits']} "
                     f"wasted={cp['prefetch_wasted']} p99_tick={p99_p:.1f}ms"))

        # --- the two acceptance asserts -------------------------------
        # blocking degrades ~linearly in RTT: the slope between the
        # smallest and largest injected RTT must carry most of the
        # deterministic stall floor (remote_fetches x delta-RTT; 0.6
        # tolerates wall noise on top of the exact injected sleeps)
        lo, hi = min(rtt_scales), max(rtt_scales)
        d_rtt = rtt * (hi - lo)
        floor = 0.6 * cp["remote_fetches"] * d_rtt
        assert walls_b[hi] - walls_b[lo] >= floor, \
            f"blocking path did not degrade linearly: " \
            f"{walls_b[hi]:.2f}s @ {hi}x vs {walls_b[lo]:.2f}s @ {lo}x " \
            f"(expected >= {floor:.2f}s of injected stall)"
        # prefetch hides the latency: within 25% of the zero-latency
        # baseline (the speculation-enabled control; wall0 guards the
        # degenerate case of a slow control run)
        bound = 1.25 * max(wall_p0, wall0)
        assert wall_p <= bound, \
            f"prefetched wall {wall_p:.2f}s exceeds 1.25x the " \
            f"zero-latency baseline ({max(wall_p0, wall0):.2f}s)"
        assert cp["prefetch_hits"] >= 0.8 * max(hits, 1), \
            f"speculation mispredicted: {cp['prefetch_hits']} prefetch " \
            f"hits vs {hits} cache hits"
    return rows


# ---------------------------------------------------------------------------
# query_churn_sweep: per-round cost vs live query count under churn — the
# consolidation tentpole's headline number.
# ---------------------------------------------------------------------------

def _churn_trace_key(trace):
    """Canonical per-round tuple stream (mirrors ``tests/conftest.trace_key``
    — inlined because benchmarks must stay importable without the test tree):
    admissions (mask), the match decision, tie-break (gallery row index), raw
    kernel score, the top-k candidate bands and the model epoch."""
    return [(r["qid"], r["f_curr"], r["phase"], r["epoch"],
             tuple(bool(x) for x in r["mask"]), bool(r["matched"]),
             int(r["match_cam"]), float(r["match_val"]), int(r["match_idx"]),
             tuple(r["topk"]))
            for r in trace]


def _drive_churn(sc, policy, pool, n_queries, steps, t0, *, wave_at,
                 shards=None, consolidate=True, guard_after=None):
    """Churn-capable drive loop: submits HALF the queries up front and the
    other half mid-sweep (tick ``wave_at``, so the late joiners enter in
    replay), records the full round trace, and returns per-tick walls so
    callers can carve out a steady-state window.  ``_drive_serving`` can't
    express mid-sweep submits, hence the local loop.  Query ``i`` anchors on
    ``pool[i % len(pool)]`` — cycling a bounded pool of distinct anchor
    visits is exactly the consolidation-friendly regime the tentpole targets
    (many live queries, far fewer distinct (cam, frame) demands)."""
    from repro.analysis import RecompileGuard

    vis, gal, feats, net = sc["vis"], sc["gal"], sc["feats"], sc["net"]
    wall0 = time.perf_counter()
    eng = rexcam.serve(sc["model"], embed_fn=lambda x: x, policy=policy,
                       geo_adj=net.geo_adjacent, shards=shards,
                       consolidate=consolidate)
    eng.t = t0

    def submit(lo, hi):
        for i in range(lo, hi):
            q = pool[i % len(pool)]
            eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))

    first = max(1, n_queries // 2)
    submit(0, first)
    trace, tick_lat, matches = [], [], 0
    guard = None
    for step_i, t in enumerate(range(t0, min(t0 + steps, vis.horizon))):
        if step_i == wave_at:
            submit(first, n_queries)      # mid-sweep churn: the second wave
        if guard_after is not None and step_i == guard_after:
            guard = RecompileGuard.for_engine(
                eng, max_new=1, label=f"churn steady after tick {step_i}")
            guard.__enter__()
        frames = {}
        for c in range(net.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        tk0 = time.perf_counter()
        matches += eng.tick(record_trace=trace)["matches"]
        tick_lat.append(time.perf_counter() - tk0)
    if guard is not None:
        guard.__exit__(None, None, None)
    return eng, trace, tick_lat, matches, time.perf_counter() - wall0


def query_churn_sweep(n_levels=(8, 64, 256), steps=180, shards=8,
                      pool_size=32):
    """The consolidation tentpole, measured and asserted: drive N live
    queries (N in ``n_levels``) over the duke topology with mid-sweep
    submits (a second wave joins at ``steps//3`` and replays in) and
    mid-sweep completions (``exit_t`` retires queries while others run),
    comparing the CONSOLIDATED fleet (one segment-masked ``reid_topk`` call
    per round over the fleet-global RoundPlan) against the UNCONSOLIDATED
    single engine (the per-frame reference ranking path).

    Asserted per N: the two are TRACE-IDENTICAL (same rounds, same
    admissions, same match values/tie-breaks — consolidation is a pure
    execution-plan change) with equal admitted/unique/embed totals.
    Asserted across N: fleet-wide embed calls and steady-state wall grow
    SUBLINEARLY in the live query count — cost at the largest N must stay
    under (hi/lo)x the second-largest's, because object-level consolidation
    keys the round's work on unique (camera, frame) demand, not on the
    query count.  A ``RecompileGuard(max_new=1)`` arms after warmup on the
    consolidated run: steady state must reuse compiled shapes.

    Shard counts above the visible device count degrade to the device count
    (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and
    ``JAX_PLATFORMS=cpu`` to sweep the full 8-way fleet on one host)."""
    import jax

    sc = duke(60)
    vis = sc["vis"]
    # anchor pool: distinct visits all exiting inside one short window, so
    # every query — including the late second wave — is actively ranking
    # the same stretch of live stream instead of idling on a far anchor
    cand = np.flatnonzero((vis.t_out >= 120) & (vis.t_out < 180))
    pool = cand[np.random.default_rng(7).permutation(len(cand))[:pool_size]]
    assert len(pool) >= 8, f"anchor window too sparse: {len(pool)} visits"
    t0 = int(vis.t_out[pool].min())
    # exit_t counts from the LAST sighting (matches re-anchor the search),
    # so a moderate horizon retires the pool's quieter entities mid-sweep
    # while dense-transit ones keep tracking: real completion churn
    policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                                 exit_t=45)
    wave_at = steps // 3
    guard_after = (2 * steps) // 3
    steady_from = wave_at + 5          # skip the wave's one growth compile

    n_dev = len(jax.devices())
    S = min(shards, n_dev)
    rows = []
    if S < shards:
        rows.append(("query_churn_sweep/duke/shards", 0.0,
                     f"degraded: {n_dev} devices visible, fleet runs "
                     f"shards={S} (set xla_force_host_platform_device_count)"))
    emb, wall = {}, {}
    for N in n_levels:
        eng_c, tr_c, lat_c, m_c, wall_c = _drive_churn(
            sc, policy, pool, N, steps, t0, wave_at=wave_at, shards=S,
            consolidate=True, guard_after=guard_after)
        eng_r, tr_r, lat_r, m_r, wall_r = _drive_churn(
            sc, policy, pool, N, steps, t0, wave_at=wave_at, shards=None,
            consolidate=False)
        assert _churn_trace_key(tr_c) == _churn_trace_key(tr_r), \
            f"N={N}: consolidated fleet trace diverged from the " \
            f"unconsolidated single engine"
        assert eng_c.admitted_steps == eng_r.admitted_steps
        assert eng_c.unique_frames == eng_r.unique_frames
        assert eng_c.frames_processed == eng_r.frames_processed, \
            f"N={N}: consolidation changed the embed-call count"
        done = sum(q.done for q in eng_c.queries.values())
        assert done > 0, f"N={N}: no mid-sweep completions (exit_t too big)"
        assert eng_c.replay_steps > 0, \
            f"N={N}: second wave never replayed (wave_at too early)"
        emb[N] = int(eng_c.frames_processed)
        wall[N] = float(sum(lat_c[steady_from:]))
        steady_r = float(sum(lat_r[steady_from:]))
        p50, p99 = _tick_pcts(lat_c)
        for config, eng, w, steady, lat, m in (
                ("consolidated_fleet", eng_c, wall_c, wall[N], lat_c, m_c),
                ("unconsolidated_single", eng_r, wall_r, steady_r, lat_r,
                 m_r)):
            cp50, cp99 = _tick_pcts(lat)
            bench_record("query_churn_sweep", scenario=sc["name"],
                         config=config, n_queries=N,
                         shards=S if config == "consolidated_fleet" else 0,
                         admitted_steps=int(eng.admitted_steps),
                         unique_frames=int(eng.unique_frames),
                         embed_calls=int(eng.frames_processed),
                         replay_steps=int(eng.replay_steps),
                         wall_s=round(w, 4), steady_wall_s=round(steady, 4),
                         p50_tick_ms=round(cp50, 3),
                         p99_tick_ms=round(cp99, 3), matches=int(m),
                         done=int(done))
        rows.append((f"query_churn_sweep/{sc['name']}/n{N}/consolidated",
                     wall[N] * 1e6 / max(N, 1),
                     f"embed_calls={emb[N]} steady_wall={wall[N]:.3f}s "
                     f"admitted_steps={eng_c.admitted_steps} "
                     f"unique_frames={eng_c.unique_frames} "
                     f"replay_steps={eng_c.replay_steps} done={done}/{N} "
                     f"matches={m_c} p99_tick={p99:.1f}ms trace=identical"))
        rows.append((f"query_churn_sweep/{sc['name']}/n{N}/unconsolidated",
                     steady_r * 1e6 / max(N, 1),
                     f"steady_wall={steady_r:.3f}s "
                     f"note=per-frame reference path, same trace"))
    # --- the acceptance asserts: sublinear in live query count ---------
    lo, hi = n_levels[-2], n_levels[-1]
    factor = hi / lo
    er = emb[hi] / max(emb[lo], 1)
    wr = wall[hi] / max(wall[lo], 1e-9)
    assert er < factor, \
        f"embed calls grew superlinearly: {emb[hi]} @ N={hi} vs " \
        f"{emb[lo]} @ N={lo} ({er:.2f}x >= {factor:.1f}x)"
    assert wr < factor, \
        f"steady wall grew superlinearly: {wall[hi]:.3f}s @ N={hi} vs " \
        f"{wall[lo]:.3f}s @ N={lo} ({wr:.2f}x >= {factor:.1f}x)"
    bench_record("query_churn_sweep", scenario=sc["name"],
                 config="sublinearity", n_lo=lo, n_hi=hi,
                 embed_ratio=round(er, 3), wall_ratio=round(wr, 3),
                 bound=factor, derived=True)
    rows.append((f"query_churn_sweep/{sc['name']}/sublinearity", 0.0,
                 f"sublinear=ok embed_n{hi}/n{lo}={er:.2f}x "
                 f"steady_wall_n{hi}/n{lo}={wr:.2f}x bound={factor:.1f}x"))
    return rows


# ---------------------------------------------------------------------------
# soak_130: the 130-camera soak — large synthetic topology, simultaneous
# churn + worker loss + drift, targeted row-wise re-profiling vs full
# rebuilds, paper-bracket savings asserted.
# ---------------------------------------------------------------------------

def reroute_hub_traffic(net, n_drift_hubs=4, moved_frac=0.7):
    """Localized drift injection for the city soaks: on the first
    ``n_drift_hubs`` hub rows, move ``moved_frac`` of the strongest (arterial)
    outgoing mass onto that hub's three WEAKEST leaf edges.  Those edges sit
    just below ``s_thresh`` in the profiled model, so after the shift phase 1
    prunes the now-dominant hops while the relaxed replay phase still admits
    them — rescues keep recall alive AND accumulate the §6 drift signal on
    exactly the rerouted source rows.  Travel times are untouched (the
    temporal windows stay truthful), which is what makes this a ROW-local
    drift: the right-sized response is re-profiling the hub rows, not the
    fleet-wide model.  Returns (shifted_net, drifted_row_ids)."""
    C = net.n_cams
    # hubs carry the concentrated entry mass — identifiable without the
    # generator's internals
    hubs = np.flatnonzero(net.entry > 1.0 / C)
    drift_rows = hubs[:n_drift_hubs]
    T = net.trans.copy()
    for h in drift_rows:
        row = T[h, :C]
        dests = np.flatnonzero(row)
        order = np.argsort(row[dests])
        boost = dests[order[:3]]           # weakest leaf edges
        take = dests[order[-3:]]           # strongest (corridor) edges
        moved = moved_frac * row[take].sum()
        row[take] *= 1.0 - moved_frac
        row[boost] += moved / len(boost)
    return dataclasses.replace(net, trans=T), drift_rows


@functools.lru_cache(maxsize=None)
def soak_city(n_cams=130, n_queries=12, t_shift=260, horizon=900, seed=9,
              anchor_hi=160):
    """The 130-camera soak world: ``clustered_city_network`` (neighborhood
    clusters + arterial corridors) with a LOCALIZED drift injection at
    ``t_shift`` — ``reroute_hub_traffic`` redirects four hub rows' arterial
    mass onto their weakest leaf edges, so most source-camera rows stay
    truthful and a row-targeted re-profile is the right-sized response.

    The profile model trains on DENSE pre-shift history (6000 entities):
    at 130 cameras the per-pair travel-time support is what bounds chain
    survival — each hop dies with probability ~1/(N+1) when the observed
    travel time falls past the N profiled samples, and that compounds over
    an entity's ~1/exit_p hops.  Queries come from the post-shift traffic
    and anchor EARLY (``t_out <= anchor_hi`` inside the shifted segment), so
    every tracked chain has runway across the drift and every reported
    recall is after the injected drift."""
    net = clustered_city_network(n_cams=n_cams, seed=seed)
    shifted, drift_rows = reroute_hub_traffic(net)
    hist = simulate_network(net, 6000, 4000, seed=seed + 1)
    model = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, n_cams)
    vis_a = simulate_network(net, 150, t_shift, seed=seed + 2)
    vis_b = simulate_network(shifted, 300, horizon - t_shift, seed=seed + 3)
    vis = concat_visits(vis_a, vis_b, t_shift)
    gal, _ = build_gallery(vis, 24)
    feats, _ = make_features(vis, int(vis.ent.max()) + 1,
                             FeatureParams(seed=seed + 3))
    q_b, gt_b = make_queries(vis_b, 8 * n_queries, seed=seed + 4)
    keep = np.flatnonzero(vis_b.t_out[q_b] <= anchor_hi)[:n_queries]
    q_b, gt_b = q_b[keep], gt_b[keep]
    q_vids = q_b + len(vis_a)
    gt_vids = np.where(gt_b >= 0, gt_b + len(vis_a), gt_b)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, t_shift=t_shift,
                drift_rows=drift_rows, name=f"city-{n_cams}")


def _drive_soak(sc, policy, *, shards=None, recal=None, churn_wave=None,
                lose_at=None, lose_worker=1):
    """Drive one engine through the soak's full churn program: half the
    queries submit at t0, the rest ``churn_wave`` steps in (replaying to
    catch up), and ``lose_at`` kills a fleet worker mid-run.  Returns
    (engine, wall_s, per-tick latencies)."""
    vis, gal, feats, net = sc["vis"], sc["gal"], sc["feats"], sc["net"]
    q_vids = sc["q_vids"]
    wall0 = time.perf_counter()
    eng = rexcam.serve(sc["model"], embed_fn=lambda x: x, policy=policy,
                       geo_adj=net.geo_adjacent, shards=shards,
                       recalibrate=recal,
                       visit_source=rexcam.visits_window_source(vis)
                       if recal is not None else None)
    t0 = int(vis.t_out[q_vids].min())
    eng.t = t0
    first = len(q_vids) if churn_wave is None else max(1, len(q_vids) // 2)
    for i in range(first):
        q = q_vids[i]
        eng.submit_query(i, feats[q], int(vis.cam[q]), int(vis.t_out[q]))
    tick_lat = []
    for step, t in enumerate(range(t0, vis.horizon)):
        if churn_wave is not None and step == churn_wave:
            for j in range(first, len(q_vids)):
                q = q_vids[j]
                eng.submit_query(j, feats[q], int(vis.cam[q]),
                                 int(vis.t_out[q]))
        if lose_at is not None and step == lose_at and shards is not None:
            eng.lose_worker(lose_worker)
        frames = {}
        for c in range(net.n_cams):
            vids = gal[c, t][gal[c, t] >= 0]
            if len(vids):
                frames[c] = feats[vids]
        eng.ingest(frames)
        tk0 = time.perf_counter()
        eng.tick()
        tick_lat.append(time.perf_counter() - tk0)
    return eng, time.perf_counter() - wall0, tick_lat


def soak_130(n_queries=12, shards=8, churn_wave=60, lose_at=120):
    """The 130-camera soak (paper §8.1's simulated-scale bracket, 23x-38x):
    drive the clustered city topology through query churn, mid-run worker
    loss and drift injection SIMULTANEOUSLY, under three configurations —

      * ``exhaustive``      scheme="all" single engine (the cost baseline
                            and the recall ceiling: no model to go stale);
      * ``targeted_fleet``  rexcam on the sharded fleet with row-TARGETED
                            recalibration (merge_reprofiled_rows) + loss;
      * ``full_single``     rexcam with FULL-rebuild recalibration, same
                            churn program (the re-profiling cost baseline).

    Asserted, per the acceptance bracket: admitted-steps savings vs
    exhaustive >= 20x at recall within 5% of exhaustive; targeted recall
    matches full-rebuild recall (within 2%) while re-profiling only a
    strict subset of rows per swap (profiler call accounting) at lower
    per-swap profiling wall.  Emits one BENCH_soak_130.json record per
    configuration plus a derived gate row — the persistent perf trajectory
    CI uploads per commit."""
    import jax

    sc = soak_city(n_queries=n_queries)
    vis, net = sc["vis"], sc["net"]
    q_vids, gt_vids = sc["q_vids"], sc["gt_vids"]
    C = net.n_cams
    n_q = len(q_vids)
    policy = rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02,
                                 exit_t=120)
    exhaustive = rexcam.SearchPolicy(scheme="all", exit_t=120)
    # city-scale trigger: localized drift on a handful of rows — a dense
    # prior keeps normalized per-pair scores small, so the trip gates on the
    # sustained rescue count; the WIDE re-profiling window matters — merged
    # rows need enough live samples that their travel-time support does not
    # regress the dense prior they replace; row_threshold keeps the targeted
    # selection to the spiking rows
    recal_kw = dict(drift_threshold=.01, min_rescues=5, cooldown=150,
                    poll_every=20, window=450)
    recal_t = rexcam.RecalibrationPolicy(targeted=True, row_threshold=.05,
                                         **recal_kw)
    recal_f = rexcam.RecalibrationPolicy(targeted=False, **recal_kw)

    n_dev = len(jax.devices())
    S = min(shards, n_dev)
    rows = []
    if S < shards:
        rows.append(("soak_130/shards", 0.0,
                     f"degraded: {n_dev} devices visible, fleet runs "
                     f"shards={S} (set xla_force_host_platform_device_count)"))

    def record(config, eng, wall, lat, recall, **extra):
        p50, p99 = _tick_pcts(lat)
        bench_record("soak_130", scenario=sc["name"], config=config,
                     n_cams=C, n_queries=n_q,
                     admitted_steps=int(eng.admitted_steps),
                     unique_frames=int(eng.unique_frames),
                     replay_steps=int(eng.replay_steps),
                     wall_s=round(wall, 4), p50_tick_ms=round(p50, 3),
                     p99_tick_ms=round(p99, 3), recall=round(recall, 4),
                     epoch=int(eng.model_epoch), **extra)

    ex, wall_e, lat_e = _drive_soak(sc, exhaustive, churn_wave=churn_wave)
    r_ex = _serving_recall(ex, vis, q_vids, gt_vids)
    record("exhaustive", ex, wall_e, lat_e, r_ex, shards=0)
    rows.append((f"soak_130/{sc['name']}/exhaustive",
                 wall_e * 1e6 / n_q,
                 f"recall={r_ex:.2f} admitted_steps={ex.admitted_steps} "
                 f"note=all-camera baseline, the recall ceiling"))

    fleet_shards = S if S >= 2 else None
    tg, wall_t, lat_t = _drive_soak(
        sc, policy, shards=fleet_shards, recal=recal_t,
        churn_wave=churn_wave,
        lose_at=lose_at if fleet_shards else None, lose_worker=1)
    r_tg = _serving_recall(tg, vis, q_vids, gt_vids)
    ctl_t = tg.recal
    record("targeted_fleet", tg, wall_t, lat_t, r_tg,
           shards=fleet_shards or 1, swaps=ctl_t.targeted_swaps,
           rows_reprofiled=int(ctl_t.rows_reprofiled),
           profile_wall_s=round(ctl_t.profile_wall, 4))

    fu, wall_f, lat_f = _drive_soak(sc, policy, recal=recal_f,
                                    churn_wave=churn_wave)
    r_fu = _serving_recall(fu, vis, q_vids, gt_vids)
    ctl_f = fu.recal
    record("full_single", fu, wall_f, lat_f, r_fu, shards=0,
           swaps=ctl_f.full_rebuilds,
           rows_reprofiled=int(ctl_f.rows_reprofiled),
           profile_wall_s=round(ctl_f.profile_wall, 4))

    # --- the acceptance gate ------------------------------------------
    savings = ex.admitted_steps / max(tg.admitted_steps, 1)
    assert savings >= 20.0, \
        f"soak_130: savings {savings:.1f}x below the 20x floor " \
        f"(paper brackets 23x-38x at city scale)"
    assert r_tg >= r_ex - 0.05, \
        f"soak_130: targeted recall {r_tg:.3f} more than 5% below the " \
        f"exhaustive ceiling {r_ex:.3f}"
    # the soak actually soaked: churn replayed, the fleet rebalanced, and
    # drift tripped at least one swap under both re-profiling modes
    assert tg.replay_steps > 0, "soak_130: late wave never replayed"
    if fleet_shards:
        assert tg.rebalances == 1, "soak_130: worker loss never rebalanced"
    assert ctl_t.targeted_swaps >= 1 and ctl_t.full_rebuilds == 0
    assert ctl_f.full_rebuilds >= 1 and ctl_f.targeted_swaps == 0
    # targeted re-profiling: same recall as full rebuilds while touching a
    # strict subset of rows, at lower per-swap profiling wall
    assert r_tg >= r_fu - 0.02, \
        f"soak_130: targeted recall {r_tg:.3f} fell behind full-rebuild " \
        f"recall {r_fu:.3f}"
    assert ctl_t.rows_reprofiled < C * ctl_t.targeted_swaps, \
        f"soak_130: targeted recal touched {ctl_t.rows_reprofiled} rows " \
        f"over {ctl_t.targeted_swaps} swaps — no better than full (C={C})"
    assert ctl_f.rows_reprofiled == C * ctl_f.full_rebuilds
    per_t = ctl_t.profile_wall / ctl_t.targeted_swaps
    per_f = ctl_f.profile_wall / ctl_f.full_rebuilds
    assert per_t < per_f, \
        f"soak_130: targeted per-swap profiling wall {per_t * 1e3:.1f}ms " \
        f"not below full-rebuild {per_f * 1e3:.1f}ms"

    bench_record("soak_130", scenario=sc["name"], config="gate",
                 savings_x=round(savings, 2),
                 recall_exhaustive=round(r_ex, 4),
                 recall_targeted=round(r_tg, 4),
                 recall_full=round(r_fu, 4),
                 rows_per_targeted_swap=round(
                     ctl_t.rows_reprofiled / ctl_t.targeted_swaps, 1),
                 profile_ms_targeted=round(per_t * 1e3, 2),
                 profile_ms_full=round(per_f * 1e3, 2), derived=True)
    rows.append((f"soak_130/{sc['name']}/gate", 0.0,
                 f"soak_gate=ok savings={savings:.1f}x "
                 f"recall_ex={r_ex:.2f} recall_targeted={r_tg:.2f} "
                 f"recall_full={r_fu:.2f} "
                 f"rows/swap={ctl_t.rows_reprofiled / ctl_t.targeted_swaps:.0f}"
                 f"/{C} profile_ms={per_t * 1e3:.1f}vs{per_f * 1e3:.1f}"))
    return rows
