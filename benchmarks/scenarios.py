"""Shared benchmark scenarios (built once, cached in-process and on disk).

Each scenario = (network, profile model, live visits, gallery, features,
queries) — profiling runs on a dedicated historical partition, live tracking
on held-out traffic, exactly the paper's §8.1 methodology.

``policy_sweep`` additionally exercises every admission scheme through the
``repro.api`` facade and reports compute-savings multipliers vs the
all-camera baseline (paper targets: 8.3x on Duke, 23-38x at city scale).
"""
from __future__ import annotations

import functools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import api as rexcam
from repro.core import (anoncampus_like_network, build_gallery, build_model,
                        duke_like_network, porto_like_network, simulate_network)
from repro.core.features import FeatureParams, make_features
from repro.core.simulate import restrict_network
from repro.core.tracker import make_queries


@functools.lru_cache(maxsize=None)
def duke(n_queries: int = 100):
    net = duke_like_network()
    vis = simulate_network(net, 2700, 5100, seed=0)   # 85 min @ 1 step/s
    gal, _ = build_gallery(vis, 24)
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                        time_limit=3000)               # profile partition
    feats, _ = make_features(vis, 2700, FeatureParams())
    q_vids, gt_vids = make_queries(vis, n_queries, seed=1)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, name="duke")


@functools.lru_cache(maxsize=None)
def anoncampus(n_queries: int = 20):
    net = anoncampus_like_network()
    vis = simulate_network(net, 700, 2100, seed=5)     # 35 min @ 1 step/s
    gal, _ = build_gallery(vis, 24)
    model = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                        time_limit=1300)
    # indoor occlusions: noisier features (paper §8.2 recall note)
    feats, _ = make_features(vis, 700, FeatureParams(noise_sigma=0.55, seed=5))
    q_vids, gt_vids = make_queries(vis, n_queries, seed=6)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, name="anoncampus")


@functools.lru_cache(maxsize=None)
def porto(n_cams: int = 130, n_queries: int = 100):
    net = porto_like_network(130)
    cams = np.arange(n_cams)
    if n_cams < 130:
        net = restrict_network(net, cams)
    # dedicated historical partition for profiling (denser statistics)
    hist = simulate_network(net, 6000, 7200, seed=11)
    model = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, net.n_cams)
    vis = simulate_network(net, 2000, 3600, seed=2)
    gal, _ = build_gallery(vis, 16)
    # city-scale identity diversity: more lookalike groups than the campus
    # sims (keeps the baseline near the paper's ~50% precision at 130 cams)
    feats, _ = make_features(vis, 2000, FeatureParams(n_clusters=400, seed=2))
    q_vids, gt_vids = make_queries(vis, n_queries, seed=3)
    return dict(net=net, vis=vis, gal=gal, model=model, feats=feats,
                q_vids=q_vids, gt_vids=gt_vids, name=f"porto{n_cams}")


# ---------------------------------------------------------------------------
# policy_sweep: every admission scheme through the repro.api facade.
# ---------------------------------------------------------------------------

SWEEP_POLICIES = (
    ("all", rexcam.SearchPolicy(scheme="all")),
    ("geo", rexcam.SearchPolicy(scheme="geo")),
    ("spatial_only", rexcam.SearchPolicy(scheme="spatial_only", s_thresh=.05)),
    ("rexcam", rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05, t_thresh=.02)),
)


def policy_sweep(scenarios=("duke", "porto130")):
    """(name, us_per_call, derived) rows: per scenario, each scheme's cost,
    recall/precision, and savings multiplier vs the all-camera baseline
    (paper Table targets: 8.3x Duke spatio-temporal, 23-38x at 130 cams)."""
    builders = {"duke": lambda: duke(60), "anoncampus": lambda: anoncampus(20),
                "porto130": lambda: porto(130, 60)}
    rows = []
    for sc_name in scenarios:
        sc = builders[sc_name]()
        base_cost = None
        for pname, policy in SWEEP_POLICIES:
            t0 = time.perf_counter()
            r = rexcam.track(sc["model"], sc["vis"], sc["gal"], sc["feats"],
                             sc["q_vids"], sc["gt_vids"], policy,
                             geo_adj=sc["net"].geo_adjacent)
            # per-query us, matching the other benchmark tables' convention
            us = (time.perf_counter() - t0) * 1e6 / max(len(sc["q_vids"]), 1)
            if pname == "all":
                base_cost = r.total_cost
            savings = base_cost / max(r.total_cost, 1.0)
            rows.append((f"policy_sweep/{sc['name']}/{pname}", us,
                         f"savings={savings:.1f}x recall={r.recall:.2f} "
                         f"precision={r.precision:.2f} "
                         f"rescued={int(r.rescued.sum())}"))
    return rows
