"""Paper §6 end-to-end: correlation drift -> detection -> re-profiling.

A road closure reroutes c1's outbound traffic mid-simulation.  The stale
spatio-temporal model starts missing transitions; the misses surface as
replay rescues concentrated on the changed camera pairs (``rescue_pairs``),
which is exactly the paper's re-profiling trigger.  Re-profiling on the
post-change window restores the savings/recall operating point.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.tables import _row
from repro.core import (TrackerParams, build_gallery, build_model,
                        duke_like_network, simulate_network, track_queries)
from repro.core.features import FeatureParams, make_features
from repro.core.profiler import drift_score
from repro.core.simulate import CameraNetwork
from repro.core.tracker import make_queries


def _rerouted(net: CameraNetwork) -> CameraNetwork:
    """Road closure: c1->c2 traffic (the strongest pair) reroutes to c1->c5 —
    a pair the profile says is UNcorrelated (S=0.005 < s_thresh), so the
    stale model prunes exactly the frames the traffic now uses."""
    T = net.trans.copy()
    moved = T[0, 1] * 0.9
    T[0, 1] -= moved
    T[0, 4] += moved
    return dataclasses.replace(net, trans=T)


def run():
    net = duke_like_network()
    changed = _rerouted(net)

    # history (pre-change) -> profile
    hist = simulate_network(net, 2000, 4000, seed=21)
    model = build_model(hist.ent, hist.cam, hist.t_in, hist.t_out, net.n_cams)

    # live traffic AFTER the road closure
    vis = simulate_network(changed, 2000, 4000, seed=22)
    gal, _ = build_gallery(vis, 24)
    feats, _ = make_features(vis, 2000, FeatureParams(seed=22))
    q_vids, gt_vids = make_queries(vis, 60, seed=23)
    p = TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02)

    base = track_queries(model, vis, gal, feats, q_vids, gt_vids, p,
                         geo_adj=net.geo_adjacent)

    # drift detection: rescue spike normalized by historical counts
    score = drift_score(model, base.rescue_pairs)
    hot = np.unravel_index(np.argmax(score), score.shape)

    # re-profile on the (changed) recent window and re-track
    model2 = build_model(vis.ent, vis.cam, vis.t_in, vis.t_out, net.n_cams,
                         time_limit=2500)
    fresh = track_queries(model2, vis, gal, feats, q_vids, gt_vids, p,
                          geo_adj=net.geo_adjacent)

    # reference: tracking the UNchanged world with the original profile
    vis0 = simulate_network(net, 2000, 4000, seed=22)
    gal0, _ = build_gallery(vis0, 24)
    feats0, _ = make_features(vis0, 2000, FeatureParams(seed=22))
    q0, g0 = make_queries(vis0, 60, seed=23)
    ref = track_queries(model, vis0, gal0, feats0, q0, g0, p,
                        geo_adj=net.geo_adjacent)

    return [
        _row("sec6_drift/no-drift-reference", 0.0, recall=ref.recall,
             rescued=int(ref.rescued.sum()), cost=ref.total_cost),
        _row("sec6_drift/stale-profile", 0.0, recall=base.recall,
             rescued=int(base.rescued.sum()), cost=base.total_cost,
             hot_pair=f"c{hot[0]+1}->c{hot[1]+1}",
             note="rescue spike localizes the changed pair (paper trigger)"),
        _row("sec6_drift/re-profiled", 0.0, recall=fresh.recall,
             rescued=int(fresh.rescued.sum()), cost=fresh.total_cost,
             note="re-profiling restores the operating point"),
    ]
