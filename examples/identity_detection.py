"""Multi-camera identity detection (paper §5.4): find a lost identity that
enters the camera network at an unknown time and place, by propagating
appearance probabilities through the spatio-temporal model.

  PYTHONPATH=src python examples/identity_detection.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (DetectorParams, build_model, duke_like_network,
                        identity_detection, simulate_network)
from repro.core.detect import make_detection_queries
from repro.core.features import FeatureParams, make_features

net = duke_like_network()
visits = simulate_network(net, 1800, 3600, seed=3)
model = build_model(visits.ent, visits.cam, visits.t_in, visits.t_out,
                    net.n_cams, time_limit=2400)
feats, _ = make_features(visits, 1800, FeatureParams(seed=3))
t_start = 2400
queries = make_detection_queries(visits, 20, search_start=t_start, seed=4)
print(f"searching for {len(queries)} lost identities from t={t_start}")

for theta in (0.95, 0.75):
    r = identity_detection(model, visits, feats, queries,
                           DetectorParams(theta=theta), t_refs=t_start)
    b = identity_detection(model, visits, feats, queries,
                           DetectorParams(theta=theta), baseline=True,
                           t_refs=t_start)
    print(f"theta={theta}: cost {r['cost']:9.0f} vs baseline {b['cost']:9.0f} "
          f"({b['cost']/max(r['cost'],1):.1f}x) | recall {r['recall']:.2f} "
          f"(baseline {b['recall']:.2f}) | precision {r['precision']:.2f} "
          f"(baseline {b['precision']:.2f})")
print("paper: 7.6x at theta=0.95; 6.6x at 0.75 with no recall drop")
