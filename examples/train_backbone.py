"""Train a ~100M-parameter analytics backbone (yi-6b family scaled down) for
a few hundred steps on CPU — the end-to-end driver of deliverable (b).

  PYTHONPATH=src python examples/train_backbone.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

sys.argv = [sys.argv[0], "--arch", "yi_6b", "--steps", "200", "--d-model", "384",
            "--layers", "6", "--seq", "256", "--batch", "8",
            "--ckpt", "/tmp/repro_train_demo"]
main()
