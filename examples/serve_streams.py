"""Serving engine demo: live camera streams through the ReXCam admission
filter into a batched inference plane (see repro/launch/serve.py for the
full driver with CLI flags).

  PYTHONPATH=src python examples/serve_streams.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

sys.argv = [sys.argv[0], "--queries", "6", "--steps", "400"]
main()
