"""Quickstart: build a correlation model from simulated history, track a
query across cameras, and compare against the all-camera baseline.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (TrackerParams, build_gallery, build_model,
                        duke_like_network, simulate_network, track_queries)
from repro.core.features import FeatureParams, make_features
from repro.core.tracker import make_queries

# 1. A calibrated 8-camera network (DukeMTMC statistics; DESIGN.md §7)
net = duke_like_network()
visits = simulate_network(net, n_entities=1200, horizon=2400, seed=0)
print(f"simulated {len(visits)} visits of 1200 identities on {net.n_cams} cameras")

# 2. Offline profiling (paper §6): historical partition -> spatio-temporal model
model = build_model(visits.ent, visits.cam, visits.t_in, visits.t_out,
                    net.n_cams, time_limit=1600)
S = np.asarray(model.S)
print(f"peers receiving >=5% of outbound traffic: {(S >= .05).sum(1).mean():.2f}"
      " per camera (paper: 1.9)")

# 3. Live tracking (paper Alg. 1): ReXCam vs the all-camera baseline
gallery, _ = build_gallery(visits, 24)
feats, _ = make_features(visits, 1200, FeatureParams())
queries, gt = make_queries(visits, 25, seed=1)

base = track_queries(model, visits, gallery, feats, queries, gt,
                     TrackerParams(scheme="all"))
rex = track_queries(model, visits, gallery, feats, queries, gt,
                    TrackerParams(scheme="rexcam", s_thresh=.05, t_thresh=.02))

print(f"\nbaseline:  {base.total_cost:9.0f} camera-frames | "
      f"recall {base.recall:.2f} | precision {base.precision:.2f}")
print(f"ReXCam:    {rex.total_cost:9.0f} camera-frames | "
      f"recall {rex.recall:.2f} | precision {rex.precision:.2f}")
print(f"compute savings: {base.total_cost / rex.total_cost:.1f}x "
      f"(paper: 8.3x on the real DukeMTMC)")
print(f"replay rescues: {int(rex.rescued.sum())} (delay {rex.mean_delay:.1f}s)")
