"""Quickstart: build a correlation model from simulated history, track a
query across cameras, and compare against the all-camera baseline — all
through the stable ``repro.api`` facade.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import api as rexcam
from repro.core import build_gallery, duke_like_network, simulate_network
from repro.core.features import FeatureParams, make_features

# 1. A calibrated 8-camera network (DukeMTMC statistics; DESIGN.md §7)
net = duke_like_network()
visits = simulate_network(net, n_entities=1200, horizon=2400, seed=0)
print(f"simulated {len(visits)} visits of 1200 identities on {net.n_cams} cameras")

# 2. Offline profiling (paper §6): historical partition -> spatio-temporal model
model = rexcam.profile(visits, time_limit=1600)
S = np.asarray(model.S)
print(f"peers receiving >=5% of outbound traffic: {(S >= .05).sum(1).mean():.2f}"
      " per camera (paper: 1.9)")

# 3. Live tracking (paper Alg. 1): ReXCam vs the all-camera baseline —
#    the same SearchPolicy/admit plane the serving engine runs.
gallery, _ = build_gallery(visits, 24)
feats, _ = make_features(visits, 1200, FeatureParams())
queries, gt = rexcam.make_queries(visits, 25, seed=1)

base = rexcam.track(model, visits, gallery, feats, queries, gt,
                    rexcam.SearchPolicy(scheme="all"))
rex = rexcam.track(model, visits, gallery, feats, queries, gt,
                   rexcam.SearchPolicy(scheme="rexcam", s_thresh=.05,
                                       t_thresh=.02))

print(f"\nbaseline:  {base.total_cost:9.0f} camera-frames | "
      f"recall {base.recall:.2f} | precision {base.precision:.2f}")
print(f"ReXCam:    {rex.total_cost:9.0f} camera-frames | "
      f"recall {rex.recall:.2f} | precision {rex.precision:.2f}")
print(f"compute savings: {base.total_cost / rex.total_cost:.1f}x "
      f"(paper: 8.3x on the real DukeMTMC)")
print(f"replay rescues: {int(rex.rescued.sum())} (delay {rex.mean_delay:.1f}s)")
